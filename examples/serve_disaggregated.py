"""End-to-end driver (deliverable b): serve a small model with batched
multi-agent requests through the REAL disaggregated engine — on the paged
KV data plane.

Actual JAX models on CPU: a frozen base prefill worker writes KV into a
shared physical page pool (``PagedKVPool``), three heterogeneous decode
workers receive ZERO-COPY handoffs (a block-table reference + page refcounts,
no tensor copy), and each turn's three agent requests are decoded together by
the continuous-batch stepper. This is the paper's §3.3 pipeline in miniature:
shared/partial prefill -> block-table handoff -> selective batched decode.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py   (~2 min)
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import LocalDisaggEngine
from repro.models import init_params

CFG = ModelConfig(name="serve-demo", arch_type="dense", n_layers=3,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=64, dtype="float32")

AGENTS = ("planner", "coder", "reviewer")


def main():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decoders = {a: init_params(CFG, jax.random.PRNGKey(7 + i))
                for i, a in enumerate(AGENTS)}
    eng = LocalDisaggEngine(CFG, base, decoders, num_pages=2048)
    assert eng.paged, "dense arch should run on the paged data plane"

    rng = np.random.default_rng(0)
    n_sessions, turns, gen_len = 4, 2, 8
    t0 = time.time()
    total_gen = 0
    # sessions advance in lockstep so each turn's requests decode TOGETHER:
    # per turn, one partial prefill per session, 3 zero-copy handoffs each,
    # and one continuous-batch drive where every agent model steps a batch
    # of n_sessions sequences at once.
    ctxs = {sid: list(rng.integers(4, 60, size=48))        # system prompts
            for sid in range(n_sessions)}
    for turn in range(turns):
        for sid in ctxs:
            ctxs[sid] += list(rng.integers(4, 60, size=12))  # obs/delta
        t1 = time.time()
        rids = {(sid, a): eng.submit(sid, ctxs[sid], a, gen_tokens=gen_len)
                for sid in ctxs for a in AGENTS}
        eng.run()
        wall = time.time() - t1
        for (sid, a), r in rids.items():
            out = eng.result(r)
            ctxs[sid] += list(out)                         # append outputs
            total_gen += len(out)
        print(f"turn {turn}: {len(rids)} requests "
              f"({n_sessions} sessions x {len(AGENTS)} agents), "
              f"ctx {len(ctxs[0]):4d} tok, wall {wall * 1e3:6.1f}ms")
    for sid in ctxs:
        eng.end_session(sid)

    dt = time.time() - t0
    s = eng.stats
    print(f"\n== summary ==")
    print(f"generated {total_gen} tokens in {dt:.1f}s "
          f"({total_gen / dt:.1f} tok/s on 1 CPU core)")
    print(f"prefill computed {s.prefill_tokens_computed} tokens, "
          f"REUSED {s.prefill_tokens_reused} (hit ratio "
          f"{100 * s.hit_ratio:.1f}%)")
    print(f"handoffs: {s.handoffs} ({s.handoff_bytes} B of block-table "
          f"metadata — the KV pages never moved)")
    print(f"decode: {s.decode_tokens} tokens in {s.decode_steps} batched "
          f"steps (mean batch {s.decode_batch_mean:.1f}), "
          f"{s.cow_page_copies} copy-on-write page clones")
    print("every agent decoded from the SAME shared base pages; in the "
          "baseline each of the 3 models would have re-prefilled the full "
          "context (3x prefill compute, 3x KV storage) and copied the "
          "whole cache on every handoff.")


if __name__ == "__main__":
    main()
