"""End-to-end driver (deliverable b): serve a small model with batched
multi-agent requests through the REAL disaggregated engine — via the
request-centric API (``repro.serving.api``).

Actual JAX models on CPU: each session is a ``SharedContext`` — ONE
prefilled prefix in the shared physical page pool (``PagedKVPool``) that
three heterogeneous decode models attach to with zero-copy handoffs (a
block-table reference + page refcounts, no tensor copy). Requests are
``RequestOutput`` streaming handles: tokens arrive per engine step (TTFT and
inter-token gaps are measured below), finish reasons are per-request, and
every turn's requests across ALL sessions and agents decode together in the
fused continuous-batch stepper. This is the paper's §3.3 pipeline in
miniature: shared/partial prefill -> block-table handoff -> selective
batched decode.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py   (~2 min)
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import lora_init
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine
from repro.serving.registry import DecodeModelSpec, LoRAAdapter

CFG = ModelConfig(name="serve-demo", arch_type="dense", n_layers=3,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=64, dtype="float32")

AGENTS = ("planner", "coder", "reviewer")


def main():
    base = init_params(CFG, jax.random.PRNGKey(0))
    eng = LocalDisaggEngine(CFG, base, num_pages=2048)
    assert eng.paged, "dense arch should run on the paged data plane"
    # the decode-model set is a LIVE lifecycle surface: agents register with
    # the engine (full fine-tunes here), and more can hot-join mid-traffic
    for i, a in enumerate(AGENTS):
        eng.models.register(a, DecodeModelSpec(
            full=init_params(CFG, jax.random.PRNGKey(7 + i))))

    rng = np.random.default_rng(0)
    n_sessions, turns, gen_len = 4, 2, 8
    t0 = time.time()
    total_gen = 0
    # one SharedContext per session: the shared prefix is a first-class API
    # object — no raw session-id bookkeeping, no manual end_session. Each
    # turn extends every context and fans the registered agents out over it;
    # the engine decodes all sessions x agents in one continuous batch.
    ctxs = {sid: eng.shared_context(rng.integers(4, 60, size=48))
            for sid in range(n_sessions)}
    ttfts, itls = [], []
    for turn in range(turns):
        if turn == 1:
            # hot-register an adapter-factored agent between turns, while
            # the engine is live: a LoRA spec stores ONE base copy + tiny
            # A/B factors, merged inside the jitted fused decode step — the
            # plane relayouts at the next step boundary and every surviving
            # stream keeps decoding bit-identically across the churn
            eng.models.register("summarizer", DecodeModelSpec(
                lora=LoRAAdapter(lora_init(jax.random.PRNGKey(42), base,
                                           rank=8))))
            print(f"hot-registered 'summarizer' (LoRA rank 8); models now: "
                  f"{eng.models.list()}")
        agents = eng.models.list()
        for ctx in ctxs.values():
            ctx.extend(rng.integers(4, 60, size=12))       # obs/delta
        t1 = time.time()
        outs = {(sid, a): ctx.generate(a, params=SamplingParams(
                    max_tokens=gen_len))
                for sid, ctx in ctxs.items() for a in agents}
        eng.run()                                          # drive to finish
        wall = time.time() - t1
        for (sid, a), out in outs.items():
            assert out.finished and out.finish_reason == "length"
            ctxs[sid].extend(out.tokens)                   # outputs join ctx
            total_gen += len(out.tokens)
            ttfts.append(out.ttft)
            itls.extend(out.inter_token_latencies())
        print(f"turn {turn}: {len(outs)} requests "
              f"({n_sessions} sessions x {len(agents)} agents), "
              f"ctx {len(ctxs[0].tokens):4d} tok, wall {wall * 1e3:6.1f}ms")
    # retire the hot-joined agent (drain=True lets in-flight work finish;
    # nothing is in flight here, so it is gone on return)
    eng.models.unregister("summarizer", drain=True)
    assert "summarizer" not in eng.models
    for ctx in ctxs.values():
        ctx.close()

    dt = time.time() - t0
    s = eng.stats
    print("\n== summary ==")
    print(f"generated {total_gen} tokens in {dt:.1f}s "
          f"({total_gen / dt:.1f} tok/s on 1 CPU core)")
    print(f"prefill computed {s.prefill_tokens_computed} tokens, "
          f"REUSED {s.prefill_tokens_reused} (hit ratio "
          f"{100 * s.hit_ratio:.1f}%)")
    print(f"handoffs: {s.handoffs} ({s.handoff_bytes} B of block-table "
          f"metadata — the KV pages never moved)")
    print(f"decode: {s.decode_tokens} tokens in {s.decode_steps} batched "
          f"steps (mean batch {s.decode_batch_mean:.1f}), "
          f"{s.cow_page_copies} copy-on-write page clones")
    print(f"model lifecycle: {s.model_churn_events} churn events, "
          f"{s.plane_rebuilds} fused-plane relayouts at step boundaries")
    print(f"streaming: mean TTFT {1e3 * float(np.mean(ttfts)):.1f}ms, "
          f"p95 inter-token gap {1e3 * float(np.percentile(itls, 95)):.1f}ms")
    print("every agent decoded from the SAME shared base pages; in the "
          "baseline each of the 3 models would have re-prefilled the full "
          "context (3x prefill compute, 3x KV storage) and copied the "
          "whole cache on every handoff.")


if __name__ == "__main__":
    main()
