"""End-to-end driver (deliverable b): serve a small model with batched
multi-agent requests through the REAL disaggregated engine.

Actual JAX models on CPU: one frozen base prefill worker, three heterogeneous
decode workers, sessions interleaving agents over a growing shared context —
incremental (partial) prefill, schema-checked cache handoff, per-invocation
metrics. This is the paper's Appendix-B.1 pipeline in miniature.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py   (~2 min)
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.engine import LocalDisaggEngine
from repro.models import init_params

CFG = ModelConfig(name="serve-demo", arch_type="dense", n_layers=3,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=64, dtype="float32")

AGENTS = ("planner", "coder", "reviewer")


def main():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decoders = {a: init_params(CFG, jax.random.PRNGKey(7 + i))
                for i, a in enumerate(AGENTS)}
    eng = LocalDisaggEngine(CFG, base, decoders, capacity=512)

    rng = np.random.default_rng(0)
    n_sessions, turns, gen_len = 4, 2, 8
    t0 = time.time()
    total_gen = 0
    for sid in range(n_sessions):
        context = list(rng.integers(4, 60, size=48))       # system prompt
        for turn in range(turns):
            for agent in AGENTS:
                context += list(rng.integers(4, 60, size=12))  # obs/delta
                t1 = time.time()
                out = eng.invoke(sid, context, agent, gen_tokens=gen_len)
                ttft = time.time() - t1
                context += list(out)
                total_gen += len(out)
                print(f"session {sid} turn {turn} {agent:9s}: ctx "
                      f"{len(context):4d} tok, gen {len(out)}, "
                      f"wall {ttft * 1e3:6.1f}ms")
        eng.end_session(sid)

    dt = time.time() - t0
    s = eng.stats
    print(f"\n== summary ==")
    print(f"generated {total_gen} tokens in {dt:.1f}s "
          f"({total_gen / dt:.1f} tok/s on 1 CPU core)")
    print(f"prefill computed {s.prefill_tokens_computed} tokens, "
          f"REUSED {s.prefill_tokens_reused} (hit ratio "
          f"{100 * s.hit_ratio:.1f}%)")
    print(f"handoffs: {s.handoffs} ({s.handoff_bytes / 1e6:.2f} MB "
          f"base-cache traffic)")
    print("every agent decoded from the SAME shared base cache; in the "
          "baseline each of the 3 models would have re-prefilled the full "
          "context (3x prefill compute, 3x KV storage).")


if __name__ == "__main__":
    main()
