"""Quickstart: PrefillShare in ~80 lines.

Pretrains a tiny base model on a task mixture, cache-conditioned-fine-tunes
TWO specialists (a "math" agent and a "copy" agent), then serves both from a
SINGLE shared prefill cache — the paper's core loop end-to-end on CPU.

Run:  PYTHONPATH=src python examples/quickstart.py  (~4 min on one core)
"""
import functools
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.prefillshare import base_prefill, cache_schema
from repro.models import init_params
from repro.models.model import train_loss
from repro.training import data as D
from repro.training.optim import AdamW, warmup_cosine
from repro.training.trainer import (Trainer, evaluate,
                                    finetune_cache_conditioned,
                                    pretrain_batches)

CFG = ModelConfig(name="quickstart", arch_type="dense", n_layers=4,
                  d_model=128, n_heads=4, n_kv_heads=4, d_ff=384,
                  vocab_size=64, dtype="float32")
SPEC = dict(n_symbols=8, prompt_len=10, vocab=64)


def main():
    print("1) pretraining the shared base (prefill module)...")
    base = init_params(CFG, jax.random.PRNGKey(0))
    tr = Trainer(functools.partial(train_loss, CFG, remat=False),
                 AdamW(warmup_cosine(3e-3, 300), weight_decay=0.01))
    base, _ = tr.fit(base, pretrain_batches(
        CFG, 0, 300, 48, spec=D.TaskSpec(domain="mix", **SPEC)),
        log_every=100, tag="pretrain")
    print(f"   base fingerprint: {cache_schema(CFG, base, 64).base_model_id}")

    print("2) cache-conditioned fine-tuning two specialists "
          "(base stays FROZEN)...")
    specialists = {}
    for domain in ("math", "copy"):
        spec = D.TaskSpec(domain=domain, **SPEC)
        dec, _ = finetune_cache_conditioned(
            CFG, base, base, domain, seed=1, steps=300, batch=48, lr=1.5e-3,
            spec=spec, log_every=150)
        specialists[domain] = dec

    print("3) serving BOTH specialists from one shared prefill cache:")
    for domain, dec in specialists.items():
        spec = D.TaskSpec(domain=domain, **SPEC)
        acc_shared = evaluate(CFG, dec, base, domain, seed=7,
                              share_ratio=1.0, spec=spec, per_token=True)
        acc_base = evaluate(CFG, base, base, domain, seed=7,
                            share_ratio=1.0, spec=spec, per_token=True)
        print(f"   {domain:6s}: specialist@shared-cache {acc_shared:.3f} "
              f"(un-finetuned base: {acc_base:.3f})")

    print("4) one prompt -> one prefill -> N decoders:")
    b = D.make_batch(__import__("numpy").random.default_rng(3),
                     D.TaskSpec(domain="math", **SPEC), 1)
    prompt = jnp.asarray(b.prompt)
    _, shared_cache = base_prefill(CFG, base, prompt,
                                   cache_len=prompt.shape[1] + 16)
    print(f"   shared cache computed once over {prompt.shape[1]} tokens; "
          f"consumed by {len(specialists)} heterogeneous decoders. Done.")


if __name__ == "__main__":
    main()
