"""Multi-model agent serving: baseline vs PrefillShare (paper Figs. 3-4).

Event-driven simulation of a 4-agent ReAct workload on TPU v5e cost terms:
prints the arrival-rate sweep and the concurrency sweep side by side.

Run:  PYTHONPATH=src python examples/multi_agent_serving.py   (~1 min)
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.serving import ServingConfig, Simulator, make_sessions


def sweep_rates(cfg, rates=(1.0, 2.0, 4.0, 8.0)):
    print(f"{'rate':>5} | {'mode':>12} | {'p95 e2e':>8} | {'tok/s':>7} | "
          f"{'TTFT':>6} | {'hit%':>5} | evic")
    for rate in rates:
        for mode in ("baseline", "prefillshare"):
            sessions = make_sessions("react", n_sessions=80,
                                     arrival_rate=rate, seed=0)
            sim = Simulator(cfg, ServingConfig(
                mode=mode, max_concurrent=64, chips_per_worker=2,
                hbm_per_worker=32e9), sessions)
            r = sim.run()
            print(f"{rate:5.1f} | {mode:>12} | {r['p95_e2e_s']:8.2f} | "
                  f"{r['throughput_tok_s']:7.0f} | {r['mean_ttft_s']:6.3f} | "
                  f"{100 * r['prefix_hit_ratio']:5.1f} | {r['evictions']}")


def sweep_concurrency(cfg, grid=(16, 32, 64, 128)):
    print(f"\n{'conc':>5} | {'mode':>12} | {'hit%':>5} | {'tok/s':>7} | staged%")
    for mc in grid:
        for mode in ("baseline", "prefillshare"):
            sessions = make_sessions("react", n_sessions=100,
                                     arrival_rate=4.0, seed=1)
            sim = Simulator(cfg, ServingConfig(
                mode=mode, max_concurrent=mc, chips_per_worker=2,
                hbm_per_worker=32e9), sessions)
            r = sim.run()
            print(f"{mc:5d} | {mode:>12} | {100 * r['prefix_hit_ratio']:5.1f} | "
                  f"{r['throughput_tok_s']:7.0f} | "
                  f"{100 * r['staged_frac']:5.1f}")


if __name__ == "__main__":
    cfg = get_config(sys.argv[1] if len(sys.argv) > 1 else "llama31-8b")
    print(f"== {cfg.name}: 4-agent ReAct, disaggregated baseline vs "
          f"PrefillShare ==")
    sweep_rates(cfg)
    sweep_concurrency(cfg)
