"""Multi-model agent serving: baseline vs PrefillShare (paper Figs. 3-4).

Two parts:
  1. REAL ENGINE (tiny model, runs anywhere): two agent models answering
     independent requests that repeat one system prompt — NO SharedContext,
     no session plumbing — and the engine-global radix prefix cache reuses
     the shared KV automatically across both prefill workers. Then a
     sequential planner -> executor -> critic pipeline where each stage's
     prompt embeds the previous stage's OUTPUT: relay KV publishes the
     decode-written pages at finish, so downstream stages skip prefill
     past upstream generations too (relay hit ratio printed alongside the
     prefix hit ratio).
  2. Event-driven simulation of a 4-agent ReAct workload on TPU v5e cost
     terms: the arrival-rate sweep and the concurrency sweep side by side.

Run:  PYTHONPATH=src python examples/multi_agent_serving.py   (~1 min)
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.serving import ServingConfig, Simulator, make_sessions


def real_engine_autoprefix():
    """Automatic prefix caching on the real jax engine: agents share a
    system prompt by accident of workload, not by API arrangement."""
    import jax
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.serving.api import SamplingParams
    from repro.serving.engine import LocalDisaggEngine

    cfg = ModelConfig(name="agents-demo", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=64, dtype="float32")
    eng = LocalDisaggEngine(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                            num_pages=256, page_size=16, chunked=True,
                            chunk_size=32, token_budget=64,
                            n_prefill_workers=2,
                            router_policy="prefix_aware")
    for i in range(2):
        eng.models.register(f"agent{i}",
                            init_params(cfg, jax.random.PRNGKey(7 + i)))

    rng = np.random.default_rng(0)
    system = list(rng.integers(4, 60, size=96))     # the shared system prompt
    for i in range(6):                              # independent requests —
        user = list(rng.integers(4, 60, size=8 + i))  # no SharedContext
        eng.generate(f"agent{i % 2}", system + user,
                     SamplingParams(max_tokens=4)).result()
    s = eng.stats()
    print("== real engine: 6 plain requests x 2 agent models, one repeated "
          "96-token system prompt, 2 prefill workers ==")
    print(f"automatic prefix reuse: {s['prefix_hit_tokens']} hit tokens / "
          f"{s['prefix_total_tokens']} prompted "
          f"(hit ratio {s['prefix_hit_ratio']:.2f}), "
          f"{s['prefix_nodes']} pages in the radix tree, "
          f"{s['evictions']} evictions\n")


def real_engine_relay_pipeline():
    """Relay KV on the real engine: a sequential agent pipeline where each
    stage reads the previous stage's output. The stages share the BASE
    module's KV path (full-weight agents over the same base), so when a
    stage finishes, its decode-written pages are published into the same
    radix tree the prefix cache uses — the next stage's prefill hits not
    just the prompt it repeats but the tokens the previous stage GENERATED."""
    import jax
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.serving.api import SamplingParams
    from repro.serving.engine import LocalDisaggEngine

    cfg = ModelConfig(name="pipeline-demo", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=64, dtype="float32")
    base = init_params(cfg, jax.random.PRNGKey(0))
    eng = LocalDisaggEngine(cfg, base, num_pages=256, page_size=16,
                            chunked=True, chunk_size=32, token_budget=64)
    for role in ("planner", "executor", "critic"):
        eng.models.register(role, base)

    task = [int(t) for t in
            np.random.default_rng(1).integers(4, 60, size=64)]
    transcript = list(task)
    for role in ("planner", "executor", "critic"):   # each stage extends the
        out = eng.generate(role, transcript,          # running transcript
                           SamplingParams(max_tokens=48)).result()
        transcript = transcript + [2] + [int(t) for t in out]
    s = eng.stats()
    print("== real engine: planner -> executor -> critic over one growing "
          "transcript (each prompt embeds the previous stage's output) ==")
    print(f"prefix reuse: {s['prefix_hit_tokens']} hit tokens "
          f"(hit ratio {s['prefix_hit_ratio']:.2f}) — of which RELAYED "
          f"decode-written tokens: {s['relay_hit_tokens']} "
          f"(relay hit ratio {s['relay_hit_ratio']:.2f}); "
          f"{s['relay_pages_published']} pages published by "
          f"{s['relay_publishes']} finishes, "
          f"{s['pages_cached_relay']}/{s['pages_cached']} cached pages are "
          f"relay-provenance\n")


def sweep_rates(cfg, rates=(1.0, 2.0, 4.0, 8.0)):
    print(f"{'rate':>5} | {'mode':>12} | {'p95 e2e':>8} | {'tok/s':>7} | "
          f"{'TTFT':>6} | {'hit%':>5} | evic")
    for rate in rates:
        for mode in ("baseline", "prefillshare"):
            sessions = make_sessions("react", n_sessions=80,
                                     arrival_rate=rate, seed=0)
            sim = Simulator(cfg, ServingConfig(
                mode=mode, max_concurrent=64, chips_per_worker=2,
                hbm_per_worker=32e9), sessions)
            r = sim.run()
            print(f"{rate:5.1f} | {mode:>12} | {r['p95_e2e_s']:8.2f} | "
                  f"{r['throughput_tok_s']:7.0f} | {r['mean_ttft_s']:6.3f} | "
                  f"{100 * r['prefix_hit_ratio']:5.1f} | {r['evictions']}")


def sweep_concurrency(cfg, grid=(16, 32, 64, 128)):
    print(f"\n{'conc':>5} | {'mode':>12} | {'hit%':>5} | {'tok/s':>7} | staged%")
    for mc in grid:
        for mode in ("baseline", "prefillshare"):
            sessions = make_sessions("react", n_sessions=100,
                                     arrival_rate=4.0, seed=1)
            sim = Simulator(cfg, ServingConfig(
                mode=mode, max_concurrent=mc, chips_per_worker=2,
                hbm_per_worker=32e9), sessions)
            r = sim.run()
            print(f"{mc:5d} | {mode:>12} | {100 * r['prefix_hit_ratio']:5.1f} | "
                  f"{r['throughput_tok_s']:7.0f} | "
                  f"{100 * r['staged_frac']:5.1f}")


if __name__ == "__main__":
    real_engine_autoprefix()
    real_engine_relay_pipeline()
    cfg = get_config(sys.argv[1] if len(sys.argv) > 1 else "llama31-8b")
    print(f"== {cfg.name}: 4-agent ReAct, disaggregated baseline vs "
          f"PrefillShare ==")
    sweep_rates(cfg)
    sweep_concurrency(cfg)
