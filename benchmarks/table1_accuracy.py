"""Paper Tables 1-2: Full-FT vs PrefillShare accuracy parity across domains
and model sizes (tiny-scale analogues of math/coding/tool-calling).

Table-1 analogue: one base, three domains (math/copy/lookup), Full-FT vs
cache-conditioned FT, each evaluated in its own serving regime (Full-FT with
self cache, PrefillShare with the shared base cache).
Table-2 analogue: same protocol across three model widths.
"""
from __future__ import annotations

import dataclasses
import sys

sys.path.insert(0, "src")

from benchmarks.fig2_sharing import TINY, train_models
from repro.training.trainer import evaluate


def run_domain(domain, cfg=TINY, steps=(400, 400)):
    cfg, spec, base, full, ps = train_models(domain, cfg=cfg,
                                             pretrain_steps=steps[0],
                                             ft_steps=steps[1])
    return {
        "domain": domain,
        "base_noft": evaluate(cfg, base, base, domain, seed=9,
                              share_ratio=1.0, spec=spec, per_token=True),
        "full_ft_selfcache": evaluate(cfg, full, base, domain, seed=9,
                                      share_ratio=0.0, spec=spec,
                                      per_token=True),
        "full_ft_sharedcache": evaluate(cfg, full, base, domain, seed=9,
                                        share_ratio=1.0, spec=spec,
                                        per_token=True),
        "prefillshare": evaluate(cfg, ps, base, domain, seed=9,
                                 share_ratio=1.0, spec=spec, per_token=True),
    }


def run(quick=True):
    steps = (300, 300) if quick else (800, 800)
    rows = [run_domain(d, steps=steps)
            for d in (("copy",) if quick else ("math", "copy", "lookup"))]
    # Table-2 analogue: scale sweep
    if not quick:
        for width in (96, 128, 192):
            cfg = dataclasses.replace(TINY, name=f"tiny-{width}",
                                      d_model=width, d_ff=3 * width)
            r = run_domain("copy", cfg=cfg, steps=steps)
            r["domain"] = f"copy@d{width}"
            rows.append(r)
    return rows


def main(quick=True):
    rows = run(quick)
    cols = ("domain", "base_noft", "full_ft_selfcache", "full_ft_sharedcache",
            "prefillshare")
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.3f}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    return rows


if __name__ == "__main__":
    main(quick="--full" not in sys.argv)
