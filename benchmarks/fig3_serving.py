"""Paper Fig. 3: serving under multi-model agent workloads.

Sweeps session arrival rate for ReAct and Reflexion; baseline vs PrefillShare;
reports p95 end-to-end latency, throughput, and TTFT. Per the paper's
protocol, each (system, rate) point picks the best max-concurrent-sessions
setting from a small sweep.

``--churn SECONDS`` prices model-lifecycle churn on top of any point: every
interval a decode model hot-(un)registers (the engine's ModelRegistry), and
the registry-rebuild cost (``ServingConfig.churn_rebuild_s``) freezes the
fused decode plane's progress for that window. ``--smoke`` runs one small
churned point end-to-end with sanity assertions (<60 s — the CI
simulator-smoke job in .github/workflows/tier1.yml).
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions


def run_point(arch, pattern, rate, mode, max_conc, n_sessions, seed=0,
              chips=2, hbm=32e9, churn_s=0.0):
    cfg = get_config(arch)
    sessions = make_sessions(pattern, n_sessions=n_sessions,
                             arrival_rate=rate, seed=seed)
    sim = Simulator(cfg, ServingConfig(mode=mode, max_concurrent=max_conc,
                                       chips_per_worker=chips,
                                       hbm_per_worker=hbm,
                                       churn_interval_s=churn_s), sessions)
    return sim.run()


def smoke(churn_s: float = 2.0) -> dict:
    """CI gate: one small ReAct point with model churn enabled, end-to-end.
    Asserts the run completes, churn events fired and were priced, and the
    churned run is no faster than the identical churn-free run."""
    quiet = run_point("internlm2-1.8b", "react", 2.0, "prefillshare", 32, 20)
    churned = run_point("internlm2-1.8b", "react", 2.0, "prefillshare", 32,
                        20, churn_s=churn_s)
    assert churned["sessions_done"] == quiet["sessions_done"] == 20
    assert churned["churn_events"] > 0 and quiet["churn_events"] == 0
    assert churned["churn_stall_s"] > 0
    assert churned["p95_e2e_s"] >= quiet["p95_e2e_s"] - 1e-9
    print("metric,quiet,churned")
    for k in ("sessions_done", "p95_e2e_s", "throughput_tok_s",
              "churn_events", "churn_stall_s"):
        print(f"{k},{quiet[k]:.4g},{churned[k]:.4g}")
    print(f"# sim-smoke OK: {churned['churn_events']} churn events priced "
          f"{churned['churn_stall_s']:.3f}s of decode-plane stall")
    return churned


def best_over_concurrency(arch, pattern, rate, mode, n_sessions,
                          conc_grid=(16, 32, 64, 128), churn_s=0.0):
    best = None
    for mc in conc_grid:
        r = run_point(arch, pattern, rate, mode, mc, n_sessions,
                      churn_s=churn_s)
        r["max_concurrent"] = mc
        if best is None or r["throughput_tok_s"] > best["throughput_tok_s"]:
            best = r
    return best


def run(quick: bool = True, arch: str = "llama31-8b", churn_s: float = 0.0):
    rates = (1.0, 2.0, 4.0) if quick else (0.5, 1.0, 2.0, 4.0, 6.0, 8.0)
    n_sessions = 60 if quick else 150
    patterns = ("react", "reflexion")
    rows = []
    for pattern in patterns:
        for rate in rates:
            for mode in ("baseline", "prefillshare"):
                if quick:
                    r = run_point(arch, pattern, rate, mode, 64, n_sessions,
                                  churn_s=churn_s)
                    r["max_concurrent"] = 64
                else:
                    r = best_over_concurrency(arch, pattern, rate, mode,
                                              n_sessions, churn_s=churn_s)
                r.update({"pattern": pattern, "rate": rate})
                rows.append(r)
    return rows


def main(quick=True, churn_s: float = 0.0):
    rows = run(quick=quick, churn_s=churn_s)
    cols = ("pattern", "rate", "mode", "p95_e2e_s", "throughput_tok_s",
            "mean_ttft_s", "prefix_hit_ratio", "evictions", "max_concurrent")
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    # headline: paper claims up to 4.5x lower p95, 3.9x higher throughput
    for pattern in ("react", "reflexion"):
        pr = [r for r in rows if r["pattern"] == pattern]
        hi = max(set(r["rate"] for r in pr))
        b = next(r for r in pr if r["rate"] == hi and r["mode"] == "baseline")
        p = next(r for r in pr if r["rate"] == hi and r["mode"] == "prefillshare")
        print(f"# {pattern}@{hi}/s: p95 {b['p95_e2e_s']/p['p95_e2e_s']:.2f}x lower, "
              f"throughput {p['throughput_tok_s']/b['throughput_tok_s']:.2f}x higher")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small churned point with assertions (CI gate)")
    ap.add_argument("--full", action="store_true",
                    help="full rate sweep with per-point concurrency search")
    ap.add_argument("--churn", type=float, nargs="?", const=2.0, default=0.0,
                    metavar="SECONDS",
                    help="model-churn interval (default 2.0 when given "
                         "without a value; 0 = off)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        main(quick=not args.full, churn_s=args.churn)
