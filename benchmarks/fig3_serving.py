"""Paper Fig. 3: serving under multi-model agent workloads.

Sweeps session arrival rate for ReAct and Reflexion; baseline vs PrefillShare;
reports p95 end-to-end latency, throughput, and TTFT. Per the paper's
protocol, each (system, rate) point picks the best max-concurrent-sessions
setting from a small sweep.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions


def run_point(arch, pattern, rate, mode, max_conc, n_sessions, seed=0,
              chips=2, hbm=32e9):
    cfg = get_config(arch)
    sessions = make_sessions(pattern, n_sessions=n_sessions,
                             arrival_rate=rate, seed=seed)
    sim = Simulator(cfg, ServingConfig(mode=mode, max_concurrent=max_conc,
                                       chips_per_worker=chips,
                                       hbm_per_worker=hbm), sessions)
    return sim.run()


def best_over_concurrency(arch, pattern, rate, mode, n_sessions,
                          conc_grid=(16, 32, 64, 128)):
    best = None
    for mc in conc_grid:
        r = run_point(arch, pattern, rate, mode, mc, n_sessions)
        r["max_concurrent"] = mc
        if best is None or r["throughput_tok_s"] > best["throughput_tok_s"]:
            best = r
    return best


def run(quick: bool = True, arch: str = "llama31-8b"):
    rates = (1.0, 2.0, 4.0) if quick else (0.5, 1.0, 2.0, 4.0, 6.0, 8.0)
    n_sessions = 60 if quick else 150
    patterns = ("react", "reflexion")
    rows = []
    for pattern in patterns:
        for rate in rates:
            for mode in ("baseline", "prefillshare"):
                if quick:
                    r = run_point(arch, pattern, rate, mode, 64, n_sessions)
                    r["max_concurrent"] = 64
                else:
                    r = best_over_concurrency(arch, pattern, rate, mode,
                                              n_sessions)
                r.update({"pattern": pattern, "rate": rate})
                rows.append(r)
    return rows


def main(quick=True):
    rows = run(quick=quick)
    cols = ("pattern", "rate", "mode", "p95_e2e_s", "throughput_tok_s",
            "mean_ttft_s", "prefix_hit_ratio", "evictions", "max_concurrent")
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    # headline: paper claims up to 4.5x lower p95, 3.9x higher throughput
    for pattern in ("react", "reflexion"):
        pr = [r for r in rows if r["pattern"] == pattern]
        hi = max(set(r["rate"] for r in pr))
        b = next(r for r in pr if r["rate"] == hi and r["mode"] == "baseline")
        p = next(r for r in pr if r["rate"] == hi and r["mode"] == "prefillshare")
        print(f"# {pattern}@{hi}/s: p95 {b['p95_e2e_s']/p['p95_e2e_s']:.2f}x lower, "
              f"throughput {p['throughput_tok_s']/b['throughput_tok_s']:.2f}x higher")
    return rows


if __name__ == "__main__":
    main(quick="--full" not in sys.argv)
