"""Oversubscription A/B: priority preemption vs hold-only backpressure.

The scenario the swap tier exists for: a tight pool is filled by long
LOW-priority decodes when short HIGH-priority requests arrive. Without
preemption the scheduler can only HOLD the newcomers until the long decodes
drain — hi-pri TTFT inherits the victims' whole remaining service time.
With ``preempt=True`` the low-priority sequences are swapped out (or
dropped-and-recomputed, whichever the measured-bandwidth cost model prices
cheaper) and the hi-pri requests get pages NOW.

Gates (all recorded in the BENCH_serving/v1 JSON):
  - hi-pri TTFT, measured in SCHEDULER STEPS (deterministic on any host),
    must be >= 1.5x lower with preemption than hold-only;
  - every output token stream must be bit-identical between the two runs
    (preemption must never change what anyone generates);
  - no thrash: no victim is parked/resumed more often than the hysteresis
    window admits, and the preempted run finishes without deadlock in a
    bounded multiple of the hold-only run's steps.

Usage: PYTHONPATH=src python benchmarks/oversub_bench.py          # full A/B
       PYTHONPATH=src python benchmarks/oversub_bench.py --smoke  # CI gate
       ... [--json PATH]   # write BENCH_serving_oversub.json
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

try:                       # script: python benchmarks/oversub_bench.py
    from bench_json import gate, write_bench_json
except ImportError:        # module: python -m benchmarks.oversub_bench
    from benchmarks.bench_json import gate, write_bench_json

import jax

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="oversub", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                  dtype="float32")
PAGE = 8
PAGES = 18      # two long decodes pin the pool; hi-pri prompts need 3 pages
N_LO, N_HI = 2, 2
LO_TOKENS, HI_TOKENS = 40, 6
_PARAMS = None


def _params():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = init_params(CFG, jax.random.PRNGKey(0))
    return _PARAMS


def run_fleet(preempt: bool, *, seeded: bool = False, mode: str | None = None):
    """One contention episode; returns (row, outputs) where hi-pri TTFT is
    counted in scheduler steps from submission to first streamed token."""
    params = _params()
    kw = dict(preempt=True, overcommit=2.0) if preempt else {}
    eng = LocalDisaggEngine(CFG, params, paged=True, num_pages=PAGES,
                            page_size=PAGE, chunked=True, **kw)
    eng.models.register("m", params)
    if mode:
        eng.swap.cfg.mode = mode
    sp = dict(temperature=0.8, top_k=8, seed=123) if seeded else {}

    lo = [eng.generate("m", [2 + i] * 9,
                       SamplingParams(max_tokens=LO_TOKENS, **sp), priority=0)
          for i in range(N_LO)]
    for _ in range(4):
        eng.step()

    first_step: dict[int, int] = {}

    def on_tok(handle, _tok, _first=first_step, _eng=eng):
        _first.setdefault(handle.request_id, _eng.scheduler.stats.steps)

    submit_step = eng.scheduler.stats.steps
    hi = [eng.generate("m", [30 + i] * 17,
                       SamplingParams(max_tokens=HI_TOKENS, **sp), priority=5,
                       stream_callback=on_tok)
          for i in range(N_HI)]
    eng.run()

    outs = [list(h.result()) for h in lo + hi]
    ttft_steps = [first_step[h.request_id] - submit_step for h in hi]
    ttft_s = [h.ttft for h in hi]
    s = eng.stats()
    resumes = (max(eng.swap.resume_counts.values(), default=0)
               if eng.swap is not None else 0)
    row = {
        "config": ("preempt" if preempt else "hold") + (
            f"/{mode}" if mode else "") + ("/seeded" if seeded else ""),
        "hi_ttft_steps_mean": float(np.mean(ttft_steps)),
        "hi_ttft_steps_max": int(max(ttft_steps)),
        "hi_p95_ttft_s": round(float(np.percentile(ttft_s, 95)), 4),
        "steps_total": eng.scheduler.stats.steps,
        "preemptions": s["preemptions"],
        "swap_out_pages": s["swap_out_pages"],
        "swap_in_pages": s["swap_in_pages"],
        "recompute_tokens": s["recompute_tokens"],
        "swap_bytes": s["swap_bytes"],
        "max_resumes": resumes,
        "pool_free_after": eng.block_pool.free_count,
    }
    return row, outs


def main(smoke: bool = False, json_path: str | None = None):
    rows = []
    hold, ref = run_fleet(False)
    pre, got = run_fleet(True)
    rows += [hold, pre]
    if not smoke:
        # forced restore paths + seeded sampling, all against their own
        # unpreempted reference
        _, ref_seeded = run_fleet(False, seeded=True)
        for mode in ("swap", "recompute"):
            r, o = run_fleet(True, mode=mode)
            assert o == ref, f"{mode}: outputs diverged from hold-only run"
            rows.append(r)
            r, o = run_fleet(True, mode=mode, seeded=True)
            assert o == ref_seeded, f"{mode}/seeded: outputs diverged"
            rows.append(r)

    cols = ["config", "hi_ttft_steps_mean", "hi_ttft_steps_max",
            "hi_p95_ttft_s", "steps_total", "preemptions", "swap_out_pages",
            "swap_in_pages", "recompute_tokens", "max_resumes",
            "pool_free_after"]
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))

    ratio = hold["hi_ttft_steps_mean"] / max(pre["hi_ttft_steps_mean"], 1e-9)
    identical = got == ref
    print(f"# hi-pri TTFT {pre['hi_ttft_steps_mean']:.1f} steps preempted vs "
          f"{hold['hi_ttft_steps_mean']:.1f} held ({ratio:.2f}x lower; "
          f"{pre['preemptions']} preemptions, bit-identical: {identical}) — "
          f"preemption converts victim service time into a bounded swap "
          f"stall instead of a hi-pri queueing delay")
    gates = {
        "hi_pri_ttft_steps_ratio": gate(ratio, 1.5),
        "outputs_bit_identical": gate(1.0 if identical else 0.0, 0.5),
        "no_thrash_max_resumes": gate(pre["max_resumes"], 3,
                                      higher_is_better=False),
        "no_deadlock_step_bound": gate(
            pre["steps_total"], 3 * hold["steps_total"],
            higher_is_better=False),
        "pool_returns_to_baseline": gate(
            abs(pre["pool_free_after"] - PAGES), 0.5,
            higher_is_better=False),
    }
    if json_path:
        write_bench_json(json_path, "oversub_bench", rows, gates=gates)
    failed = [k for k, g in gates.items() if not g["passed"]]
    assert not failed, f"oversubscription gates failed: {failed}"
    return rows, gates


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: hold vs preempt A/B only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving_oversub.json here")
    args = ap.parse_args()
    main(smoke=args.smoke, json_path=args.json)
