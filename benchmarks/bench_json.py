"""Shared ``BENCH_serving.json`` writer for the serving benchmarks.

Every serving bench (`chunked_prefill_bench`, `paged_decode_bench`,
`autoscale_sim`) accepts ``--json PATH`` and writes one document in this
schema, so successive runs accumulate a comparable bench trajectory and CI
can upload the file as an artifact:

    {
      "schema": "BENCH_serving/v1",
      "bench":  "<bench name>",
      "unix_time": <int seconds>,
      "rows":  [ {<mode/path label>, tok_s, *_ms | *_s percentiles,
                  hit_ratio, ...}, ... ],
      "gates": { "<gate name>": {"value": float, "threshold": float,
                 "passed": bool}, ... }
    }

Rows are the bench's printed table verbatim (one dict per configuration);
gates are the assertions the bench enforces, recorded with the measured
value so a regression's margin is visible in the artifact history, not just
pass/fail.
"""
from __future__ import annotations

import json
import time

SCHEMA = "BENCH_serving/v1"


def gate(value: float, threshold: float, *, higher_is_better: bool = True):
    """One recorded assertion: the measured value vs its gate threshold."""
    passed = value > threshold if higher_is_better else value < threshold
    return {"value": float(value), "threshold": float(threshold),
            "higher_is_better": higher_is_better, "passed": bool(passed)}


def write_bench_json(path: str, bench: str, rows: list,
                     gates: dict | None = None) -> dict:
    doc = {
        "schema": SCHEMA,
        "bench": bench,
        "unix_time": int(time.time()),
        "rows": rows,
        "gates": gates or {},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({SCHEMA}, bench={bench}, {len(rows)} rows)")
    return doc
