"""Diurnal autoscale scenario: elastic prefill:decode split vs every static.

The workload flips regime mid-run (serving/workload.py DIURNAL_PHASES):

  phase A — "daytime" ingest burst: single-turn sessions with ~4k-token cold
            prompts and 16-token answers arriving at 8x the base rate.
            Prefill queueing dominates TTFT; generated KV drains instantly,
            so decode is never the constraint — every worker parked on
            decode is wasted.
  phase B — "evening" chat: 3-turn sessions, 48-token deltas, 512-token
            generations. Prompt work is trivial but accumulated multi-turn
            KV saturates decode HBM, so TTFT degrades through deferred
            handoffs (B.2 backpressure) unless decode holds the workers.

No static split is right for both phases — that is the point. The
autoscaler (serving/autoscale.py) starts at the neutral 4:4 and must
discover the schedule from its signals alone: it shifts workers toward
prefill when the phase-A backlog builds, and back toward decode in phase B
*proactively*, on declining KV headroom (free_page_frac), before the first
deferral lands. The gate asserts the autoscaled run's pooled p95 TTFT beats
EVERY static split of the same 8-worker fleet.

The pooled p95 is an honest diurnal metric here: phase A's tail punishes
decode-heavy statics (2:6 drowns in prefill queueing) while phase B's tail
punishes prefill-heavy ones (5:3+ avalanches into handoff deferral), so a
static split can win one phase only by losing the other.

Usage: PYTHONPATH=src python benchmarks/autoscale_sim.py          # full sweep
       PYTHONPATH=src python benchmarks/autoscale_sim.py --smoke  # CI, <60 s
       PYTHONPATH=src python benchmarks/autoscale_sim.py --prom-lint
       ... [--json PATH]   # write BENCH_serving.json
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

try:                       # script: python benchmarks/autoscale_sim.py
    from bench_json import gate, write_bench_json
except ImportError:        # module: python -m benchmarks.autoscale_sim
    from benchmarks.bench_json import gate, write_bench_json
from repro.configs.base import get_config
from repro.serving.autoscale import AutoscaleConfig
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_diurnal_sessions

CFG = get_config("internlm2-1.8b")

TOTAL = 8                  # fixed fleet: n_prefill + n_decode
N_SESSIONS = 60            # 30 per phase
RATE = 5.0                 # base Poisson session arrival rate (1/s)
GAP_S = 8.0                # drain gap between the phases (regime boundary)

# Frozen control-loop tuning for the gate. The bounds exclude the 1:7/7:1
# corners (both phases' p95 there are bistable deferral cliffs), and
# free_page_low=0.35 is the proactive mark: decode HBM headroom declines
# for seconds before the first deferral, so shifting at 35% free completes
# the migration while handoffs still flow.
AUTOSCALE = AutoscaleConfig(
    min_prefill=2, max_prefill=6, min_decode=2, max_decode=6,
    decode_slots=24, total_budget=TOTAL, interval_s=0.25,
    cooldown_intervals=0, ttft_target_s=None,
    backlog_high_s=0.45, backlog_low_s=0.01, free_page_low=0.35)


def run_split(n_pre: int, n_dec: int, *, seed: int = 0,
              autoscale: AutoscaleConfig | None = None) -> dict:
    sessions = make_diurnal_sessions(n_sessions=N_SESSIONS, arrival_rate=RATE,
                                     seed=seed, phase_gap_s=GAP_S)
    sc = ServingConfig(mode="prefillshare", n_prefill_workers=n_pre,
                       n_decode_workers=n_dec, max_concurrent=96,
                       chips_per_worker=1, hbm_per_worker=8e9,
                       b2_policy="backpressure", prefill_chunk_tokens=256,
                       max_decode_batch=16, autoscale=autoscale)
    sim = Simulator(CFG, sc, sessions)
    r = sim.run()
    recs = [x for x in sim.records if x.done > 0]
    half = N_SESSIONS // 2
    a = [x.ttft for x in recs if x.sid < half]
    b = [x.ttft for x in recs if x.sid >= half]
    return {
        "split": f"{n_pre}:{n_dec}",
        "autoscaled": autoscale is not None,
        "p95_ttft_s": round(r["p95_ttft_s"], 4),
        "phase_a_p95_ttft_s": round(float(np.percentile(a, 95)), 4),
        "phase_b_p95_ttft_s": round(float(np.percentile(b, 95)), 4),
        "p95_e2e_s": round(r["p95_e2e_s"], 3),
        "tok_s": round(r["throughput_tok_s"], 1),
        "resizes": r["resize_events"],
        "final_split": (f"{r['final_prefill_workers']}:"
                        f"{r['final_decode_workers']}"),
    }


def main(smoke: bool = False, seed: int = 0, json_path: str | None = None):
    # smoke trims the sweep to the competitive statics (the corners lose by
    # an order of magnitude; the full run shows them) to stay under the CI
    # 60 s budget
    prefills = range(2, 6) if smoke else range(1, TOTAL)
    rows = [run_split(p, TOTAL - p, seed=seed) for p in prefills]
    auto = run_split(4, 4, seed=seed, autoscale=AUTOSCALE)
    rows.append(auto)

    cols = ["split", "p95_ttft_s", "phase_a_p95_ttft_s", "phase_b_p95_ttft_s",
            "p95_e2e_s", "tok_s", "resizes", "final_split"]
    print(",".join(cols))
    for r in rows:
        tag = "auto " + r["split"] if r["autoscaled"] else "     " + r["split"]
        print(",".join([tag] + [str(r[c]) for c in cols[1:]]))

    statics = [r for r in rows if not r["autoscaled"]]
    best = min(statics, key=lambda r: r["p95_ttft_s"])
    margin = best["p95_ttft_s"] / auto["p95_ttft_s"]
    print(f"# autoscale p95 TTFT {auto['p95_ttft_s']:.3f}s vs best static "
          f"{best['split']} {best['p95_ttft_s']:.3f}s ({margin:.2f}x lower; "
          f"{auto['resizes']} resizes, 4:4 start -> {auto['final_split']}) — "
          f"phase A favors prefill, phase B decode, and only the elastic "
          f"split serves both tails")
    if json_path:
        write_bench_json(json_path, "autoscale_sim", rows, gates={
            "autoscale_beats_best_static_p95_ttft": gate(
                margin, 1.0, higher_is_better=True)})
    assert auto["p95_ttft_s"] < best["p95_ttft_s"], (
        f"autoscale p95 TTFT {auto['p95_ttft_s']:.3f}s did not beat best "
        f"static {best['split']} at {best['p95_ttft_s']:.3f}s")
    return rows, margin


def prom_lint():
    """Scrape a real engine's ``render_prometheus()`` through the format
    lint: a tiny model serves a few requests so every registry family
    (counters, gauges, TTFT/ITL histograms, traces) is populated, then the
    exposition text must lint clean and carry the core series."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.serving.api import SamplingParams
    from repro.serving.engine import LocalDisaggEngine
    from repro.serving.metrics import lint_prometheus

    cfg = ModelConfig(name="prom-lint", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, dtype="float32")
    eng = LocalDisaggEngine(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                            num_pages=128, page_size=16)
    eng.models.register("m0", init_params(cfg, jax.random.PRNGKey(7)))
    rng = np.random.default_rng(0)
    outs = [eng.generate("m0", list(rng.integers(4, 60, size=24 + i)),
                        SamplingParams(max_tokens=8)) for i in range(3)]
    eng.run()
    assert all(o.finished for o in outs)

    text = eng.render_prometheus()
    problems = lint_prometheus(text)
    assert not problems, "\n".join(problems)
    for series in ("engine_ttft_seconds", "engine_itl_seconds",
                   "engine_decode_tokens_total", "engine_pool_free_pages"):
        assert series in text, f"missing core series {series!r}"
    n_series = sum(1 for ln in text.splitlines()
                   if ln and not ln.startswith("#"))
    print(f"# prometheus lint clean: {n_series} samples, "
          f"{text.count('# TYPE')} families")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: competitive statics only, <60 s")
    ap.add_argument("--prom-lint", action="store_true",
                    help="lint a real engine's Prometheus exposition")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving.json here")
    args = ap.parse_args()
    if args.prom_lint:
        prom_lint()
        sys.exit(0)
    main(smoke=args.smoke, seed=args.seed, json_path=args.json)
