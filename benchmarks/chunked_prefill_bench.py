"""Chunked prefill vs eager whole-prompt prefill under a mixed workload.

Workload: a handful of steady decode sequences (short prompts, long
generations) with several LONG prompts arriving mid-stream — the paper's
prefill-decode interference scenario. Two engines, same models, same greedy
outputs:

  eager    — chunking off: an arriving long prompt is prefilled whole,
             synchronously, stalling every decode step behind it
             (head-of-line blocking).
  chunked  — the token-budget scheduler slices the long prompts into chunks
             co-scheduled with decode, so steady sequences keep emitting
             tokens while the long prefills progress.

Reports decode inter-token latency (mean/p95 across the steady sequences'
token gaps) and aggregate generated tokens/s. Expected: chunking trades a
little aggregate throughput for a MUCH lower decode p95 — the long-prompt
stall disappears from the steady sequences' gap distribution.

Usage: PYTHONPATH=src python -m benchmarks.chunked_prefill_bench
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="chunk-bench", arch_type="dense", n_layers=3,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=64, dtype="float32")

N_STEADY = 4
STEADY_GEN = 24
LONG_LEN = 320
LONG_GEN = 4
INJECT_EVERY = 6          # steps between long-prompt arrivals


def _workload(seed: int):
    rng = np.random.default_rng(seed)
    steady = [list(rng.integers(4, 60, size=16 + 2 * i))
              for i in range(N_STEADY)]
    longs = [list(rng.integers(4, 60, size=LONG_LEN)) for _ in range(3)]
    return steady, longs


def _drive(eng: LocalDisaggEngine, steady, longs):
    """Run the mixed workload on ``eng``; returns (itl_samples, wall, toks)."""
    # warm the compile caches on a throwaway copy of the workload so the
    # measured gaps are compute, not tracing
    for sid, ctx in enumerate(steady):
        eng.submit(1000 + sid, ctx, "m0", gen_tokens=2)
    eng.submit(1100, longs[0], "m0", gen_tokens=2)
    eng.run()
    for sid in range(N_STEADY):
        eng.end_session(1000 + sid)
    eng.end_session(1100)

    rids = [eng.submit(sid, ctx, "m0", gen_tokens=STEADY_GEN)
            for sid, ctx in enumerate(steady)]
    steady_rids = set(rids)
    itl, last, prev = [], {}, {r: 0 for r in rids}
    injected = 0
    steps = 0
    total_tokens = 0
    t_start = time.perf_counter()
    while eng.scheduler.has_work():
        if steps and steps % INJECT_EVERY == 0 and injected < len(longs):
            eng.submit(100 + injected, longs[injected], "m0",
                       gen_tokens=LONG_GEN)
            injected += 1
        eng.step()
        now = time.perf_counter()
        steps += 1
        for s in list(eng.scheduler.active):
            if s.rid not in steady_rids:
                continue
            n = len(s.out)
            if n > prev[s.rid]:
                if s.rid in last:
                    gap = (now - last[s.rid]) / (n - prev[s.rid])
                    itl.extend([gap] * (n - prev[s.rid]))
                last[s.rid] = now
                prev[s.rid] = n
    wall = time.perf_counter() - t_start
    total_tokens = N_STEADY * STEADY_GEN + injected * LONG_GEN
    for sid in range(N_STEADY):
        eng.end_session(sid)
    for i in range(injected):
        eng.end_session(100 + i)
    return itl, wall, total_tokens


def main(chunk_size: int = 32, token_budget: int = 48, seed: int = 0):
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {"m0": init_params(CFG, jax.random.PRNGKey(7))}
    steady, longs = _workload(seed)

    rows = []
    for mode, kw in (
            ("eager", dict()),
            ("chunked", dict(chunked=True, chunk_size=chunk_size,
                             token_budget=token_budget))):
        eng = LocalDisaggEngine(CFG, base, decs, num_pages=512, page_size=16,
                                **kw)
        itl, wall, toks = _drive(eng, steady, longs)
        rows.append({
            "mode": mode,
            "itl_mean_ms": 1e3 * float(np.mean(itl)),
            "itl_p95_ms": 1e3 * float(np.percentile(itl, 95)),
            "tok_s": toks / wall,
            "chunks": eng.scheduler.stats.chunks,
        })

    print("mode,itl_mean_ms,itl_p95_ms,tok_s,prefill_chunks")
    for r in rows:
        print(f"{r['mode']},{r['itl_mean_ms']:.2f},{r['itl_p95_ms']:.2f},"
              f"{r['tok_s']:.1f},{r['chunks']}")
    eager, chunked = rows
    ratio = eager["itl_p95_ms"] / chunked["itl_p95_ms"]
    print(f"# decode p95 ITL: {eager['itl_p95_ms']:.2f}ms eager -> "
          f"{chunked['itl_p95_ms']:.2f}ms chunked ({ratio:.2f}x lower)")
    return rows, ratio


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--budget", type=int, default=48)
    args = ap.parse_args()
    _, ratio = main(chunk_size=args.chunk, token_budget=args.budget)
    assert ratio > 1.0, (
        f"chunking did not lower decode p95 (ratio {ratio:.2f}x)")
