"""Chunked prefill vs eager whole-prompt prefill under a mixed workload.

Workload: a handful of steady decode sequences (short prompts, long
generations) with several LONG prompts arriving mid-stream — the paper's
prefill-decode interference scenario. Two engines, same models, same greedy
outputs:

  eager    — chunking off: an arriving long prompt is prefilled whole,
             synchronously, stalling every decode step behind it
             (head-of-line blocking).
  chunked  — the token-budget scheduler slices the long prompts into chunks
             co-scheduled with decode, so steady sequences keep emitting
             tokens while the long prefills progress.

Latency comes from the REQUEST-CENTRIC API's streaming outputs: every
request is a ``RequestOutput`` whose per-token timestamps are recorded at
push time, so TTFT and inter-token-latency percentiles here are exactly
what a streaming client would observe (not an end-to-end proxy):

  - steady streams: ITL mean/p50/p95 across token gaps, plus TTFT p95 —
    chunking removes the long-prompt stall from the gap distribution;
  - long prompts: TTFT p95 — the cost chunking pays, a long prompt's own
    first token arrives later because its prefill is sliced.

A second A/B (``prefix_ab``) measures AUTOMATIC prefix caching: a fleet of
independent requests repeating one long system prompt — no SharedContext,
two prefill workers, hit-aware routing — run once with the engine-global
radix tree on (the default) and once with ``prefix_cache=False``. Gates:
token streams bit-identical, fleet hit tokens > 0.5x the shareable prefix
tokens, and steady-stream p95 TTFT lower with the cache on (followers skip
straight past the cached prefix to their first token).

Usage: PYTHONPATH=src python -m benchmarks.chunked_prefill_bench
       PYTHONPATH=src python benchmarks/chunked_prefill_bench.py --prefix-smoke
       ... [--json PATH]   # write BENCH_serving.json (see bench_json.py)
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

try:
    from bench_json import gate, write_bench_json
except ImportError:
    from benchmarks.bench_json import gate, write_bench_json

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="chunk-bench", arch_type="dense", n_layers=3,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=64, dtype="float32")

N_STEADY = 4
STEADY_GEN = 24
LONG_LEN = 320
LONG_GEN = 4
INJECT_EVERY = 6          # steps between long-prompt arrivals


def _workload(seed: int):
    rng = np.random.default_rng(seed)
    steady = [list(rng.integers(4, 60, size=16 + 2 * i))
              for i in range(N_STEADY)]
    longs = [list(rng.integers(4, 60, size=LONG_LEN)) for _ in range(3)]
    return steady, longs


def _drive(eng: LocalDisaggEngine, steady, longs):
    """Run the mixed workload on ``eng``; returns (steady RequestOutputs,
    long RequestOutputs, wall seconds, generated tokens)."""
    # warm the compile caches on a throwaway copy of the workload so the
    # measured gaps are compute, not tracing
    warm = [eng.generate("m0", ctx, SamplingParams(max_tokens=2))
            for ctx in steady]
    warm.append(eng.generate("m0", longs[0], SamplingParams(max_tokens=2)))
    eng.run()
    assert all(w.finished for w in warm)

    t_start = time.perf_counter()
    outs = [eng.generate("m0", ctx, SamplingParams(max_tokens=STEADY_GEN))
            for ctx in steady]
    long_outs = []
    steps = 0
    while eng.scheduler.has_work():
        if (steps and steps % INJECT_EVERY == 0
                and len(long_outs) < len(longs)):
            long_outs.append(eng.generate(
                "m0", longs[len(long_outs)], SamplingParams(max_tokens=LONG_GEN)))
        eng.step()
        steps += 1
    wall = time.perf_counter() - t_start
    toks = sum(len(o.tokens) for o in outs + long_outs)
    assert all(o.finished for o in outs + long_outs)
    return outs, long_outs, wall, toks


def _pct(xs, q):
    return 1e3 * float(np.percentile(xs, q)) if len(xs) else float("nan")


def main(chunk_size: int = 32, token_budget: int = 48, seed: int = 0):
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {"m0": init_params(CFG, jax.random.PRNGKey(7))}
    steady, longs = _workload(seed)

    rows = []
    for mode, kw in (
            ("eager", dict()),
            ("chunked", dict(chunked=True, chunk_size=chunk_size,
                             token_budget=token_budget))):
        eng = LocalDisaggEngine(CFG, base, num_pages=512, page_size=16, **kw)
        for mid, p in decs.items():
            eng.models.register(mid, p)
        outs, long_outs, wall, toks = _drive(eng, steady, longs)
        itl = [g for o in outs for g in o.inter_token_latencies()]
        rows.append({
            "mode": mode,
            "itl_mean_ms": 1e3 * float(np.mean(itl)),
            "itl_p50_ms": _pct(itl, 50),
            "itl_p95_ms": _pct(itl, 95),
            "ttft_p95_ms": _pct([o.ttft for o in outs], 95),
            "long_ttft_p95_ms": _pct([o.ttft for o in long_outs], 95),
            "tok_s": toks / wall,
            "chunks": eng.scheduler.stats.chunks,
        })

    cols = ["mode", "itl_mean_ms", "itl_p50_ms", "itl_p95_ms", "ttft_p95_ms",
            "long_ttft_p95_ms", "tok_s", "chunks"]
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.2f}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    eager, chunked = rows
    ratio = eager["ttft_p95_ms"] / chunked["ttft_p95_ms"]
    print(f"# steady-stream p95 TTFT: {eager['ttft_p95_ms']:.2f}ms eager -> "
          f"{chunked['ttft_p95_ms']:.2f}ms chunked ({ratio:.2f}x lower) — "
          f"arriving streams are no longer blocked behind whole-prompt "
          f"prefills; p95 ITL {eager['itl_p95_ms']:.2f} -> "
          f"{chunked['itl_p95_ms']:.2f}ms, long-prompt p95 TTFT "
          f"{eager['long_ttft_p95_ms']:.2f} -> "
          f"{chunked['long_ttft_p95_ms']:.2f}ms (the slicing tradeoff)")
    return rows, ratio


# ----------------------------------------------------------------------
# automatic prefix caching A/B

PREFIX_LEN = 192          # shared system prompt (12 pages of 16)
PREFIX_FLEET = 8          # independent requests repeating it
PREFIX_GEN = 6


def _prefix_workload(seed: int, prefix_len: int, fleet: int):
    rng = np.random.default_rng(seed + 1)
    shared = list(rng.integers(4, 60, size=prefix_len))
    tails = [list(rng.integers(4, 60, size=12 + 2 * i)) for i in range(fleet)]
    return shared, tails


def _drive_prefix(eng: LocalDisaggEngine, shared, tails, gen: int):
    """Publisher + steady follower stream; returns (streams, wall, ttfts).
    Every request is a PLAIN generate — no SharedContext, no shared session:
    reuse is purely the engine-global radix tree (or absent, cache off)."""
    warm = eng.generate("m0", shared[:32] + tails[0][:4],
                        SamplingParams(max_tokens=2))
    eng.run()
    assert warm.finished

    pub = eng.generate("m0", shared + tails[0], SamplingParams(max_tokens=gen))
    eng.run()                    # publisher commits the shared prefix (if on)

    t_start = time.perf_counter()
    outs = []
    pending = list(tails[1:])
    while eng.scheduler.has_work() or pending:
        if pending:              # one arrival per step: a steady stream
            outs.append(eng.generate("m0", shared + pending.pop(0),
                                     SamplingParams(max_tokens=gen)))
        eng.step()
    wall = time.perf_counter() - t_start
    assert all(o.finished for o in [pub] + outs)
    streams = [list(o.tokens) for o in [pub] + outs]
    return streams, wall, [o.ttft for o in outs]


def prefix_ab(chunk_size: int = 32, token_budget: int = 64, seed: int = 0,
              prefix_len: int = PREFIX_LEN, fleet: int = PREFIX_FLEET,
              gen: int = PREFIX_GEN, gate_ttft: bool = True):
    base = init_params(CFG, jax.random.PRNGKey(0))
    dec = init_params(CFG, jax.random.PRNGKey(7))
    shared, tails = _prefix_workload(seed, prefix_len, fleet)

    rows, all_streams = [], []
    for mode, on in (("cache_on", True), ("cache_off", False)):
        eng = LocalDisaggEngine(CFG, base, num_pages=512, page_size=16,
                                chunked=True, chunk_size=chunk_size,
                                token_budget=token_budget,
                                n_prefill_workers=2,
                                router_policy="prefix_aware",
                                prefix_cache=on)
        eng.models.register("m0", dec)
        streams, wall, ttfts = _drive_prefix(eng, shared, tails, gen)
        s = eng.stats()
        rows.append({
            "mode": mode,
            "ttft_p95_ms": _pct(ttfts, 95),
            "ttft_p50_ms": _pct(ttfts, 50),
            "hit_tokens": s["prefix_hit_tokens"],
            "hit_ratio": s["prefix_hit_ratio"],
            "workers_hit": sum(w.mgr.stats.lookups > 0
                               for w in eng.prefill_workers),
            "tok_s": sum(len(st) for st in streams) / wall,
        })
        all_streams.append(streams)
        if on:     # the fleet really spread over BOTH prefill workers
            assert rows[-1]["workers_hit"] == 2, rows[-1]

    cols = ["mode", "ttft_p95_ms", "ttft_p50_ms", "hit_tokens", "hit_ratio",
            "workers_hit", "tok_s"]
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.2f}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))

    on_row, off_row = rows
    assert all_streams[0] == all_streams[1], \
        "prefix cache changed tokens — reuse must be bit-identical"
    shareable = (fleet - 1) * (prefix_len // 16) * 16
    assert on_row["hit_tokens"] > 0.5 * shareable, \
        (on_row["hit_tokens"], shareable)
    assert off_row["hit_tokens"] == 0
    speed = off_row["ttft_p95_ms"] / on_row["ttft_p95_ms"]
    print(f"# repeated-prefix fleet ({fleet} requests x {prefix_len}-token "
          f"shared prompt, 2 prefill workers, no SharedContext): "
          f"{on_row['hit_tokens']} hit tokens "
          f"(fleet hit ratio {on_row['hit_ratio']:.2f}), follower p95 TTFT "
          f"{off_row['ttft_p95_ms']:.2f}ms off -> {on_row['ttft_p95_ms']:.2f}"
          f"ms on ({speed:.2f}x lower), outputs bit-identical")
    if gate_ttft:
        assert speed > 1.0, (
            f"prefix cache did not lower follower p95 TTFT ({speed:.2f}x)")
    return rows, speed


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--budget", type=int, default=48)
    ap.add_argument("--prefix-smoke", action="store_true",
                    help="CI smoke: small prefix-cache A/B only (asserts "
                         "hit ratio > 0 and bit-identical outputs; the TTFT "
                         "gate is reserved for the full bench)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving.json here")
    args = ap.parse_args()
    if args.prefix_smoke:
        rows, _ = prefix_ab(token_budget=args.budget + 16, prefix_len=96,
                            fleet=4, gen=4, gate_ttft=False)
        if args.json:
            write_bench_json(args.json, "chunked_prefill_prefix_smoke", rows,
                             gates={"fleet_hit_ratio": gate(
                                 rows[0]["hit_ratio"], 0.0)})
        assert rows[0]["hit_ratio"] > 0.0
        sys.exit(0)
    rows, ratio = main(chunk_size=args.chunk, token_budget=args.budget)
    prefix_rows, speed = prefix_ab(chunk_size=args.chunk)
    if args.json:
        write_bench_json(args.json, "chunked_prefill", rows + prefix_rows,
                         gates={
                             "steady_ttft_p95_eager_over_chunked": gate(
                                 ratio, 1.0),
                             "follower_ttft_p95_off_over_on": gate(
                                 speed, 1.0)})
    # the robust user-visible win on this workload: a stream arriving under
    # load reaches its FIRST token far sooner when long prompts are sliced
    # (ITL percentiles are reported above; on toy CPU models the per-chunk
    # paged-attention overhead can eat the ITL win that motivates chunking
    # at scale, so TTFT is the gated metric)
    assert ratio > 1.0, (
        f"chunking did not lower steady-stream p95 TTFT (ratio {ratio:.2f}x)")
