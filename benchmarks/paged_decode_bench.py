"""Batched paged decode vs the seed B=1 dense loop (acceptance benchmark).

Same real models, same greedy outputs, two execution paths:

  dense-B1  — the seed engine's path: dense per-session prefill, full-cache
              ``transfer_cache`` handoff copy, then a Python B=1 decode loop
              per sequence (one un-jitted forward per token per sequence).
  paged     — the paged data plane: pool prefill + zero-copy block-table
              handoff, then CONTINUOUS-BATCH decode (all sequences advance
              one token per jitted batched step over the shared page pool).

Prints tokens/s for both and the speedup; also cross-checks that both paths
emit identical greedy tokens. Expected: >= 2x at batch >= 4 (batching removes
the per-token Python/dispatch overhead; on TPU the paged Pallas kernel also
amortizes each K/V page fetch across the GQA group).

Usage: PYTHONPATH=src python -m benchmarks.paged_decode_bench [--batch 4]
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="bench", arch_type="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")


def main(batch: int = 4, gen: int = 32, ctx_len: int = 48, seed: int = 0):
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {"m0": init_params(CFG, jax.random.PRNGKey(7))}
    rng = np.random.default_rng(seed)
    ctxs = [list(rng.integers(4, 60, size=ctx_len + i)) for i in range(batch)]

    # --- paged continuous batching -----------------------------------
    eng = LocalDisaggEngine(CFG, base, decs, num_pages=2048)
    rids = [eng.submit(sid, c, "m0", gen_tokens=gen)
            for sid, c in enumerate(ctxs)]
    t0 = time.perf_counter()
    eng.run()
    t_paged = time.perf_counter() - t0
    paged_out = [eng.result(r) for r in rids]
    paged_tps = batch * gen / t_paged

    # --- seed path: dense handoff copy + B=1 loop --------------------
    dense = LocalDisaggEngine(CFG, base, decs, capacity=1024, paged=False)
    t_dense = 0.0
    dense_out = []
    for sid, c in enumerate(ctxs):
        sc = dense.prefill_workers[0].prefill(sid, c)   # not timed: decode bench
        from repro.kvcache.handoff import transfer_cache
        cache = transfer_cache(sc.cache)
        t0 = time.perf_counter()
        dense_out.append(dense.decoders["m0"].generate(
            cache, sc.n_tokens, 2, gen))
        t_dense += time.perf_counter() - t0
    dense_tps = batch * gen / t_dense

    for a, b in zip(paged_out, dense_out):
        np.testing.assert_array_equal(a, b)

    rows = [{"path": "dense-B1", "tok_s": dense_tps, "batch": 1},
            {"path": "paged-batched", "tok_s": paged_tps, "batch": batch}]
    print("path,batch,tok_s")
    for r in rows:
        print(f"{r['path']},{r['batch']},{r['tok_s']:.1f}")
    speedup = paged_tps / dense_tps
    print(f"# speedup={speedup:.2f}x (greedy outputs identical, "
          f"mean decode batch={eng.stats.decode_batch_mean:.1f}, "
          f"handoff_bytes={eng.stats.handoff_bytes})")
    return rows, speedup


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ctx", type=int, default=48)
    args = ap.parse_args()
    _, speedup = main(batch=args.batch, gen=args.gen, ctx_len=args.ctx)
    assert speedup >= 2.0, f"batched paged decode only {speedup:.2f}x"
