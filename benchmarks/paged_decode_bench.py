"""Batched paged decode vs the seed B=1 dense loop (acceptance benchmark),
plus the fused cross-model decode plane vs the per-model dispatch loop.

Same real models, same greedy outputs, execution paths:

  dense-B1  — the seed engine's path: dense per-session prefill, full-cache
              ``transfer_cache`` handoff copy, then a Python B=1 decode loop
              per sequence (one un-jitted forward per token per sequence).
  paged     — the paged data plane: pool prefill + zero-copy block-table
              handoff, then CONTINUOUS-BATCH decode (all sequences advance
              one token per jitted batched step over the shared page pool).

``--models N > 1`` adds the multi-model workload: N task-specific decoders
fan out over shared contexts, comparing

  per-model — one jitted forward per decode model per step (fused=False),
  fused     — stacked decoder params, ONE vmapped jitted forward per step
              for every active sequence of every model (serving/decode.py),

reporting dispatches/step and tokens/s for both, with greedy outputs
asserted identical.

``--adapters`` adds the weight-side memory comparison (Eq. 9, weight side):
the same N decode models registered as LoRA specs
(``engine.models.register(mid, DecodeModelSpec(lora=...))`` — one base copy
+ N stacked A/B factors, merged inside the jitted vmapped step) vs
registered as N materialized ``lora_apply`` full models. Reports decode-
plane weight bytes for both layouts (the N×full / (base + N·adapters) ratio
is asserted against the array shapes) and tok/s of the in-step merge vs the
materialized plane, with greedy outputs asserted bit-identical.

Usage: PYTHONPATH=src python -m benchmarks.paged_decode_bench
           [--batch 4] [--models 4] [--adapters] [--json PATH]
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

try:
    from bench_json import gate, write_bench_json
except ImportError:
    from benchmarks.bench_json import gate, write_bench_json

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import LoRAPair, lora_apply, lora_init
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine
from repro.serving.registry import DecodeModelSpec, LoRAAdapter

CFG = ModelConfig(name="bench", arch_type="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")


def main(batch: int = 4, gen: int = 32, ctx_len: int = 48, seed: int = 0):
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {"m0": init_params(CFG, jax.random.PRNGKey(7))}
    rng = np.random.default_rng(seed)
    ctxs = [list(rng.integers(4, 60, size=ctx_len + i)) for i in range(batch)]

    # --- paged continuous batching -----------------------------------
    eng = LocalDisaggEngine(CFG, base, num_pages=2048)
    for mid, p in decs.items():
        eng.models.register(mid, p)
    outs = [eng.generate("m0", c, SamplingParams(max_tokens=gen), session=sid)
            for sid, c in enumerate(ctxs)]
    t0 = time.perf_counter()
    eng.run()
    t_paged = time.perf_counter() - t0
    paged_out = [o.result() for o in outs]
    paged_tps = batch * gen / t_paged

    # --- seed path: dense handoff copy + B=1 loop --------------------
    dense = LocalDisaggEngine(CFG, base, capacity=1024, paged=False)
    for mid, p in decs.items():
        dense.models.register(mid, p)
    t_dense = 0.0
    dense_out = []
    for sid, c in enumerate(ctxs):
        sc = dense.prefill_workers[0].prefill(sid, c)   # not timed: decode bench
        from repro.kvcache.handoff import transfer_cache
        cache = transfer_cache(sc.cache)
        t0 = time.perf_counter()
        toks, _ = dense.decoders["m0"].generate(
            cache, sc.n_tokens, 2, SamplingParams(max_tokens=gen))
        dense_out.append(toks)
        t_dense += time.perf_counter() - t0
    dense_tps = batch * gen / t_dense

    for a, b in zip(paged_out, dense_out):
        np.testing.assert_array_equal(a, b)

    rows = [{"path": "dense-B1", "tok_s": dense_tps, "batch": 1},
            {"path": "paged-batched", "tok_s": paged_tps, "batch": batch}]
    print("path,batch,tok_s")
    for r in rows:
        print(f"{r['path']},{r['batch']},{r['tok_s']:.1f}")
    speedup = paged_tps / dense_tps
    print(f"# speedup={speedup:.2f}x (greedy outputs identical, "
          f"mean decode batch={eng.stats.decode_batch_mean:.1f}, "
          f"handoff_bytes={eng.stats.handoff_bytes})")
    return rows, speedup


def multi_model(n_models: int = 4, seqs_per_model: int = 2, gen: int = 32,
                ctx_len: int = 48, seed: int = 0):
    """Agent fan-out workload: every session's context is decoded by several
    task-specific models over ONE shared prefill. Reports dispatches/step and
    tokens/s for the per-model loop vs the fused vmapped step."""
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(7 + i))
            for i in range(n_models)}
    rng = np.random.default_rng(seed)
    # ONE context per session, fanned out to every model (the paper's agent
    # pattern): sibling submits reuse the session's pages, so the decode
    # plane — not prefill — dominates the measured window.
    ctxs = [list(rng.integers(4, 60, size=ctx_len + 2 * sid))
            for sid in range(seqs_per_model)]
    jobs = [(sid, ctxs[sid], f"m{i}")
            for sid in range(seqs_per_model)
            for i in range(n_models)]

    def run(fused):
        eng = LocalDisaggEngine(CFG, base, num_pages=2048, fused=fused)
        for mid, p in decs.items():
            eng.models.register(mid, p)
        ros = [eng.generate(mid, ctx, SamplingParams(max_tokens=gen),
                            session=sid)
               for sid, ctx, mid in jobs]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        outs = [o.result() for o in ros]
        return (outs, len(jobs) * gen / dt,
                eng.stats.decode_dispatches / max(1, eng.stats.decode_steps),
                eng)

    loop_out, loop_tps, loop_dps, _ = run(fused=False)
    fused_out, fused_tps, fused_dps, eng = run(fused=True)
    for a, b in zip(fused_out, loop_out):
        np.testing.assert_array_equal(a, b)
    assert fused_dps == 1.0, f"fused plane issued {fused_dps} dispatches/step"

    rows = [{"path": "per-model-loop", "models": n_models, "tok_s": loop_tps,
             "dispatches_per_step": loop_dps},
            {"path": "fused-vmapped", "models": n_models, "tok_s": fused_tps,
             "dispatches_per_step": fused_dps}]
    print("path,models,dispatches_per_step,tok_s")
    for r in rows:
        print(f"{r['path']},{r['models']},{r['dispatches_per_step']:.1f},"
              f"{r['tok_s']:.1f}")
    print(f"# fused speedup={fused_tps / loop_tps:.2f}x over per-model loop "
          f"(greedy outputs identical, {n_models} models, "
          f"{len(jobs)} sequences, traces={eng.decode_plane.traces})")
    return rows, fused_tps / loop_tps


def _random_adapter(key, base, rank: int, alpha: float) -> LoRAAdapter:
    """A lora_init adapter with nonzero B, so every model's merge is a real
    task-specific perturbation (B=0 would make all N models decode as the
    base and trivialize the parity check)."""
    tree = lora_init(key, base, rank=rank)
    flat, td = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: x is None or isinstance(x, LoRAPair))
    kb = jax.random.fold_in(key, 1)
    out = []
    for i, p in enumerate(flat):
        if p is None:
            out.append(None)
        else:
            b = 0.02 * jax.random.normal(jax.random.fold_in(kb, i),
                                         p.B.shape, p.B.dtype)
            out.append(LoRAPair(p.A, b))
    return LoRAAdapter(jax.tree_util.tree_unflatten(td, out),
                       alpha=alpha, rank=rank)


def adapters_mode(n_models: int = 4, seqs_per_model: int = 2, gen: int = 32,
                  ctx_len: int = 48, seed: int = 0, rank: int = 8,
                  alpha: float = 16.0):
    """Adapter-factored decode plane vs N materialized models: same N LoRA
    fine-tunes, registered either as LoRA specs (one base copy + N stacked
    A/B factor sets, merged inside the jitted vmapped step) or as N full
    ``lora_apply`` pytrees. Reports weight bytes + tok/s; outputs asserted
    bit-identical."""
    base = init_params(CFG, jax.random.PRNGKey(0))
    ads = {f"m{i}": _random_adapter(jax.random.PRNGKey(7 + i), base,
                                    rank, alpha)
           for i in range(n_models)}
    rng = np.random.default_rng(seed)
    ctxs = [list(rng.integers(4, 60, size=ctx_len + 2 * sid))
            for sid in range(seqs_per_model)]
    jobs = [(sid, ctxs[sid], mid)
            for sid in range(seqs_per_model) for mid in ads]

    def run(lora: bool):
        eng = LocalDisaggEngine(CFG, base, num_pages=2048)
        for mid, ad in ads.items():
            spec = (DecodeModelSpec(lora=ad) if lora else
                    DecodeModelSpec(full=lora_apply(
                        base, ad.params, alpha=alpha, rank=rank)))
            eng.models.register(mid, spec)
        ros = [eng.generate(mid, ctx, SamplingParams(max_tokens=gen),
                            session=sid)
               for sid, ctx, mid in jobs]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        return [o.result() for o in ros], len(jobs) * gen / dt, eng

    full_out, full_tps, eng_full = run(lora=False)
    lora_out, lora_tps, eng_lora = run(lora=True)
    for a, b in zip(lora_out, full_out):
        np.testing.assert_array_equal(a, b)

    base_bytes = sum(x.nbytes for x in jax.tree.leaves(base))
    one_full = sum(x.nbytes for x in jax.tree.leaves(
        lora_apply(base, ads["m0"].params, alpha=alpha, rank=rank)))
    one_ad = sum(x.nbytes for x in jax.tree.leaves(ads["m0"].params))
    full_bytes = eng_full.decode_plane.param_bytes()          # N × full
    lora_bytes = base_bytes + eng_lora.decode_plane.param_bytes()  # base + N·ad
    # plane accounting must agree exactly with the array shapes
    assert full_bytes == n_models * one_full, (full_bytes, n_models, one_full)
    assert lora_bytes == base_bytes + n_models * one_ad, \
        (lora_bytes, base_bytes, n_models, one_ad)
    ratio = full_bytes / lora_bytes

    print("path,models,plane_weight_bytes,tok_s")
    print(f"materialized-full,{n_models},{full_bytes},{full_tps:.1f}")
    print(f"lora-instep-merge,{n_models},{lora_bytes},{lora_tps:.1f}")
    print(f"# weight ratio N*full/(base+N*adapters) = {ratio:.2f}x "
          f"(rank {rank}, outputs bit-identical, tok/s parity "
          f"{lora_tps / full_tps:.2f}x)")
    return ratio, lora_tps / full_tps


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ctx", type=int, default=48)
    ap.add_argument("--models", type=int, default=4)
    ap.add_argument("--adapters", action="store_true",
                    help="LoRA-spec'd plane (base + N adapters, in-step "
                         "merge) vs N materialized models")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving.json here")
    args = ap.parse_args()
    all_rows, gates = [], {}
    rows, speedup = main(batch=args.batch, gen=args.gen, ctx_len=args.ctx)
    all_rows += rows
    gates["paged_over_dense_tok_s"] = gate(speedup, 2.0)
    if args.models > 1:
        rows, fused_speedup = multi_model(n_models=args.models, gen=args.gen,
                                          ctx_len=args.ctx)
        all_rows += rows
        gates["fused_over_loop_tok_s"] = gate(fused_speedup, 0.0)
    if args.adapters:
        ratio, parity = adapters_mode(n_models=args.models, gen=args.gen,
                                      ctx_len=args.ctx)
        gates["adapter_weight_ratio"] = gate(ratio, 1.5)
        gates["adapter_tok_s_parity"] = gate(parity, 0.0)
    if args.json:
        write_bench_json(args.json, "paged_decode", all_rows, gates=gates)
    assert gates["paged_over_dense_tok_s"]["passed"], \
        f"batched paged decode only {speedup:.2f}x"
    if args.adapters:
        assert gates["adapter_weight_ratio"]["passed"], \
            f"adapter factoring saved only {gates['adapter_weight_ratio']}"
