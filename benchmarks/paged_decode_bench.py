"""Batched paged decode vs the seed B=1 dense loop (acceptance benchmark),
plus the fused cross-model decode plane vs the per-model dispatch loop.

Same real models, same greedy outputs, execution paths:

  dense-B1  — the seed engine's path: dense per-session prefill, full-cache
              ``transfer_cache`` handoff copy, then a Python B=1 decode loop
              per sequence (one un-jitted forward per token per sequence).
  paged     — the paged data plane: pool prefill + zero-copy block-table
              handoff, then CONTINUOUS-BATCH decode (all sequences advance
              one token per jitted batched step over the shared page pool).

``--models N > 1`` adds the multi-model workload: N task-specific decoders
fan out over shared contexts, comparing

  per-model — one jitted forward per decode model per step (fused=False),
  fused     — stacked decoder params, ONE vmapped jitted forward per step
              for every active sequence of every model (serving/decode.py),

reporting dispatches/step and tokens/s for both, with greedy outputs
asserted identical.

Usage: PYTHONPATH=src python -m benchmarks.paged_decode_bench
           [--batch 4] [--models 4]
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="bench", arch_type="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")


def main(batch: int = 4, gen: int = 32, ctx_len: int = 48, seed: int = 0):
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {"m0": init_params(CFG, jax.random.PRNGKey(7))}
    rng = np.random.default_rng(seed)
    ctxs = [list(rng.integers(4, 60, size=ctx_len + i)) for i in range(batch)]

    # --- paged continuous batching -----------------------------------
    eng = LocalDisaggEngine(CFG, base, decs, num_pages=2048)
    outs = [eng.generate("m0", c, SamplingParams(max_tokens=gen), session=sid)
            for sid, c in enumerate(ctxs)]
    t0 = time.perf_counter()
    eng.run()
    t_paged = time.perf_counter() - t0
    paged_out = [o.result() for o in outs]
    paged_tps = batch * gen / t_paged

    # --- seed path: dense handoff copy + B=1 loop --------------------
    dense = LocalDisaggEngine(CFG, base, decs, capacity=1024, paged=False)
    t_dense = 0.0
    dense_out = []
    for sid, c in enumerate(ctxs):
        sc = dense.prefill_workers[0].prefill(sid, c)   # not timed: decode bench
        from repro.kvcache.handoff import transfer_cache
        cache = transfer_cache(sc.cache)
        t0 = time.perf_counter()
        toks, _ = dense.decoders["m0"].generate(
            cache, sc.n_tokens, 2, SamplingParams(max_tokens=gen))
        dense_out.append(toks)
        t_dense += time.perf_counter() - t0
    dense_tps = batch * gen / t_dense

    for a, b in zip(paged_out, dense_out):
        np.testing.assert_array_equal(a, b)

    rows = [{"path": "dense-B1", "tok_s": dense_tps, "batch": 1},
            {"path": "paged-batched", "tok_s": paged_tps, "batch": batch}]
    print("path,batch,tok_s")
    for r in rows:
        print(f"{r['path']},{r['batch']},{r['tok_s']:.1f}")
    speedup = paged_tps / dense_tps
    print(f"# speedup={speedup:.2f}x (greedy outputs identical, "
          f"mean decode batch={eng.stats.decode_batch_mean:.1f}, "
          f"handoff_bytes={eng.stats.handoff_bytes})")
    return rows, speedup


def multi_model(n_models: int = 4, seqs_per_model: int = 2, gen: int = 32,
                ctx_len: int = 48, seed: int = 0):
    """Agent fan-out workload: every session's context is decoded by several
    task-specific models over ONE shared prefill. Reports dispatches/step and
    tokens/s for the per-model loop vs the fused vmapped step."""
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(7 + i))
            for i in range(n_models)}
    rng = np.random.default_rng(seed)
    # ONE context per session, fanned out to every model (the paper's agent
    # pattern): sibling submits reuse the session's pages, so the decode
    # plane — not prefill — dominates the measured window.
    ctxs = [list(rng.integers(4, 60, size=ctx_len + 2 * sid))
            for sid in range(seqs_per_model)]
    jobs = [(sid, ctxs[sid], f"m{i}")
            for sid in range(seqs_per_model)
            for i in range(n_models)]

    def run(fused):
        eng = LocalDisaggEngine(CFG, base, decs, num_pages=2048, fused=fused)
        ros = [eng.generate(mid, ctx, SamplingParams(max_tokens=gen),
                            session=sid)
               for sid, ctx, mid in jobs]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        outs = [o.result() for o in ros]
        return (outs, len(jobs) * gen / dt,
                eng.stats.decode_dispatches / max(1, eng.stats.decode_steps),
                eng)

    loop_out, loop_tps, loop_dps, _ = run(fused=False)
    fused_out, fused_tps, fused_dps, eng = run(fused=True)
    for a, b in zip(fused_out, loop_out):
        np.testing.assert_array_equal(a, b)
    assert fused_dps == 1.0, f"fused plane issued {fused_dps} dispatches/step"

    rows = [{"path": "per-model-loop", "models": n_models, "tok_s": loop_tps,
             "dispatches_per_step": loop_dps},
            {"path": "fused-vmapped", "models": n_models, "tok_s": fused_tps,
             "dispatches_per_step": fused_dps}]
    print("path,models,dispatches_per_step,tok_s")
    for r in rows:
        print(f"{r['path']},{r['models']},{r['dispatches_per_step']:.1f},"
              f"{r['tok_s']:.1f}")
    print(f"# fused speedup={fused_tps / loop_tps:.2f}x over per-model loop "
          f"(greedy outputs identical, {n_models} models, "
          f"{len(jobs)} sequences, traces={eng.decode_plane.traces})")
    return rows, fused_tps / loop_tps


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ctx", type=int, default=48)
    ap.add_argument("--models", type=int, default=4)
    args = ap.parse_args()
    _, speedup = main(batch=args.batch, gen=args.gen, ctx_len=args.ctx)
    assert speedup >= 2.0, f"batched paged decode only {speedup:.2f}x"
    if args.models > 1:
        multi_model(n_models=args.models, gen=args.gen, ctx_len=args.ctx)
