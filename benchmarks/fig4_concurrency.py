"""Paper Fig. 4: prefix cache hit ratio + throughput vs max concurrent sessions.

Fixed arrival rate (4 sessions/s, ReAct), sweep the admission cap. The paper's
observations to reproduce: baseline hit-ratio peaks (~60%) then collapses as
per-model KV pools saturate; PrefillShare stays ~89% flat and throughput keeps
rising until decode-side handoff/staging pressure (B.2) saturates it.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions


def run(quick: bool = True, arch: str = "llama31-8b", rate: float = 4.0):
    grid = (8, 16, 32, 64, 128) if quick else (8, 16, 24, 32, 48, 64, 96, 128, 192)
    n_sessions = 80 if quick else 200
    cfg = get_config(arch)
    rows = []
    for mode in ("baseline", "prefillshare"):
        for mc in grid:
            sessions = make_sessions("react", n_sessions=n_sessions,
                                     arrival_rate=rate, seed=1)
            sim = Simulator(cfg, ServingConfig(
                mode=mode, max_concurrent=mc, chips_per_worker=2,
                hbm_per_worker=32e9), sessions)
            r = sim.run()
            r.update({"max_concurrent": mc})
            rows.append(r)
    return rows


def main(quick=True):
    rows = run(quick=quick)
    cols = ("mode", "max_concurrent", "prefix_hit_ratio", "throughput_tok_s",
            "p95_e2e_s", "evictions", "staged_frac")
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    return rows


if __name__ == "__main__":
    main(quick="--full" not in sys.argv)
