"""Paper Appendix B.3 (Figs. 5-6): the serving experiments replicated with a
Qwen3-14B backbone instead of LLaMA3.1-8B — identical workloads/settings."""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks import fig3_serving, fig4_concurrency


def run(quick: bool = True):
    rows3 = fig3_serving.run(quick=quick, arch="qwen3-14b")
    rows4 = fig4_concurrency.run(quick=quick, arch="qwen3-14b")
    return rows3, rows4


def main(quick=True):
    rows3, rows4 = run(quick=quick)
    print("pattern,rate,mode,p95_e2e_s,throughput_tok_s,prefix_hit_ratio")
    for r in rows3:
        print(f"{r['pattern']},{r['rate']},{r['mode']},{r['p95_e2e_s']:.3f},"
              f"{r['throughput_tok_s']:.0f},{r['prefix_hit_ratio']:.3f}")
    print("mode,max_concurrent,prefix_hit_ratio,throughput_tok_s")
    for r in rows4:
        print(f"{r['mode']},{r['max_concurrent']},{r['prefix_hit_ratio']:.3f},"
              f"{r['throughput_tok_s']:.0f}")
    return rows3, rows4


if __name__ == "__main__":
    main(quick="--full" not in sys.argv)
