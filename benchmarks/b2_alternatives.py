"""BEYOND-PAPER: Appendix-B.2 alternatives study.

The paper ships with vLLM's staging behaviour at high concurrency and leaves
"stricter admission control, decode-to-prefill backpressure, or per-session
reservation" as future work. We implement all three
(repro/serving/backpressure.py) and sweep them at the concurrency levels
where Fig. 4's throughput rolls over.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.serving.backpressure import POLICIES
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions


def run(quick: bool = True, arch: str = "llama31-8b"):
    cfg = get_config(arch)
    rows = []
    rates = (4.0, 6.0) if quick else (2.0, 4.0, 6.0, 8.0)
    n = 60 if quick else 150
    for rate in rates:
        for pol in POLICIES:
            sessions = make_sessions("react", n_sessions=n,
                                     arrival_rate=rate, seed=2)
            sim = Simulator(cfg, ServingConfig(
                mode="prefillshare", max_concurrent=160,
                chips_per_worker=2, hbm_per_worker=24e9,
                b2_policy=pol), sessions)
            r = sim.run()
            r.update({"policy": pol, "rate": rate})
            rows.append(r)
    return rows


def main(quick=True):
    rows = run(quick)
    cols = ("rate", "policy", "throughput_tok_s", "p95_e2e_s", "mean_ttft_s",
            "staged_frac")
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    hi = max(r["rate"] for r in rows)
    base = next(r for r in rows if r["rate"] == hi and r["policy"] == "staging")
    best = max((r for r in rows if r["rate"] == hi),
               key=lambda r: r["throughput_tok_s"])
    print(f"# best policy @ {hi}/s: {best['policy']} "
          f"({best['throughput_tok_s'] / base['throughput_tok_s']:.2f}x "
          f"throughput vs paper's staging behaviour)")
    return rows


if __name__ == "__main__":
    main(quick="--full" not in sys.argv)
