"""Paper §3.3 efficiency analysis: Eq. 8 vs Eq. 9 memory scaling.

Analytic: Mem_baseline = N·(L_shared + L_unique) vs
          Mem_prefillshare = L_shared + N·L_unique,
and MEASURED from the simulator's paged pools (peak blocks held across the
prefill pool) for the same workload, confirming the structural claim.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.kvcache.manager import kv_bytes_per_token
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions


def analytic(cfg, n_models, l_shared, l_unique):
    per_tok = kv_bytes_per_token(cfg)
    base = n_models * (l_shared + l_unique) * per_tok
    ps = (l_shared + n_models * l_unique) * per_tok
    return base, ps


def measured(cfg, mode, n_sessions=40, rate=2.0):
    sessions = make_sessions("react", n_sessions=n_sessions, arrival_rate=rate)
    sim = Simulator(cfg, ServingConfig(mode=mode, max_concurrent=64,
                                       chips_per_worker=2, hbm_per_worker=32e9),
                    sessions)
    sim.run()
    peak_blocks = sum(w.mgr.pool.stats.peak_used for w in sim.prefill)
    stored_blocks = sum(w.mgr.pool.num_blocks - len(w.mgr.pool._free)
                        for w in sim.prefill)
    bpb = sim.prefill[0].mgr.bytes_per_block
    return {"peak_bytes": peak_blocks * bpb, "resident_bytes": stored_blocks * bpb}


def run(quick=True, arch="llama31-8b"):
    cfg = get_config(arch)
    rows = []
    for n in (2, 4, 8):
        b, p = analytic(cfg, n, l_shared=3500, l_unique=128)
        rows.append({"kind": "analytic", "n_models": n,
                     "baseline_gb": b / 1e9, "prefillshare_gb": p / 1e9,
                     "ratio": b / p})
    # resident (data-holding) pages, not active-refcount peak: prefill pages
    # are released to CACHED state right after handoff, so refcount peaks
    # only see in-flight requests; the Eq. 8/9 claim is about RETAINED prefix
    # state, which is resident (free-list excluded) pages.
    mb = measured(cfg, "baseline")
    mp = measured(cfg, "prefillshare")
    rows.append({"kind": "measured-resident", "n_models": 4,
                 "baseline_gb": mb["resident_bytes"] / 1e9,
                 "prefillshare_gb": mp["resident_bytes"] / 1e9,
                 "ratio": mb["resident_bytes"] / max(mp["resident_bytes"], 1)})
    return rows


def main(quick=True):
    rows = run(quick)
    print("kind,n_models,baseline_gb,prefillshare_gb,ratio")
    for r in rows:
        print(f"{r['kind']},{r['n_models']},{r['baseline_gb']:.3f},"
              f"{r['prefillshare_gb']:.3f},{r['ratio']:.2f}")
    return rows


if __name__ == "__main__":
    main()
