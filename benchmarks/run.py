"""Benchmark harness — one entry per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints one ``name,us_per_call,derived`` CSV line per benchmark (us_per_call =
wall time of the bench; derived = its headline metric), plus each benchmark's
own CSV block. The heavy training benches (fig2/table1) run in quick mode by
default; --full runs paper-scale sweeps.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")


def _bench_fig3(quick):
    from benchmarks import fig3_serving
    rows = fig3_serving.main(quick=quick)
    hi = max(r["rate"] for r in rows)
    b = next(r for r in rows if r["rate"] == hi and r["mode"] == "baseline"
             and r["pattern"] == "react")
    p = next(r for r in rows if r["rate"] == hi and r["mode"] == "prefillshare"
             and r["pattern"] == "react")
    return f"p95_speedup={b['p95_e2e_s'] / p['p95_e2e_s']:.2f}x"


def _bench_fig4(quick):
    from benchmarks import fig4_concurrency
    rows = fig4_concurrency.main(quick=quick)
    ps = [r for r in rows if r["mode"] == "prefillshare"]
    return f"ps_hit_ratio={max(r['prefix_hit_ratio'] for r in ps):.2f}"


def _bench_memory(quick):
    from benchmarks import memory_model
    rows = memory_model.main(quick=quick)
    return f"mem_ratio_4models={rows[1]['ratio']:.2f}x"


def _bench_fig2(quick):
    from benchmarks import fig2_sharing
    rows = fig2_sharing.main(quick=quick)
    full_at_1 = next(r for r in rows if r["ratio"] == 1.0)
    return (f"naive@1.0={full_at_1['full_ft']:.2f},"
            f"ps@1.0={full_at_1['prefillshare']:.2f}")


def _bench_table1(quick):
    from benchmarks import table1_accuracy
    rows = table1_accuracy.main(quick=quick)
    r = rows[0]
    return (f"fullft={r['full_ft_selfcache']:.2f},"
            f"ps={r['prefillshare']:.2f}")


def _bench_b2(quick):
    from benchmarks import b2_alternatives
    rows = b2_alternatives.main(quick=quick)
    hi = max(r["rate"] for r in rows)
    best = max((r for r in rows if r["rate"] == hi),
               key=lambda r: r["throughput_tok_s"])
    return f"best_policy={best['policy']}"


def _bench_roofline(quick):
    from benchmarks import roofline
    rows = roofline.analyze()
    ok = [r for r in rows if "error" not in r and "skipped" not in r]
    if not ok:
        return "no-dryrun-data"
    doms = [r["dominant"] for r in ok]
    return f"combos={len(ok)},compute_bound={doms.count('compute')}"


def _bench_kernels(quick):
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import ref_flash_prefill
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 8, 64))
    k = jax.random.normal(key, (1, 256, 4, 64))
    o = flash_attention(q, k, k, interpret=True)
    r = ref_flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          k.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    return f"flash_maxerr={float(jnp.abs(o - r).max()):.1e}"


BENCHES = [
    ("fig3_serving", _bench_fig3),
    ("fig4_concurrency", _bench_fig4),
    ("memory_model_eq8_9", _bench_memory),
    ("b2_alternatives_beyond_paper", _bench_b2),
    ("roofline", _bench_roofline),
    ("kernels_allclose", _bench_kernels),
    ("fig2_sharing", _bench_fig2),
    ("table1_accuracy", _bench_table1),
]


def main() -> None:
    quick = "--full" not in sys.argv
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
    summary = []
    for name, fn in BENCHES:
        if only and only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            derived = fn(quick)
        except Exception as e:  # noqa: BLE001
            derived = f"ERROR:{type(e).__name__}:{e}"
        us = (time.time() - t0) * 1e6
        summary.append((name, us, derived))
    print("\nname,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
