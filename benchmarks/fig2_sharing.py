"""Paper Fig. 2: accuracy vs KV-cache sharing ratio.

Trains (tiny-scale, CPU):
  base     — pretrained on the task mixture (the frozen prefill module),
  full     — Full-FT on the target domain (standard fine-tuning),
  ps       — cache-conditioned FT on the target domain (PrefillShare).

Then evaluates across share ratios 0..1: the fraction of layers whose prompt
cache comes from the BASE model rather than the decode model's own prefill.
Expected reproduction of the paper's curve: Full-FT collapses as ratio -> 1
(naive sharing), PrefillShare holds near its ratio-0... ratio-1 operating
point (it was *trained* at ratio 1).
"""
from __future__ import annotations

import functools
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.models.model import train_loss
from repro.training import data as D
from repro.training.optim import AdamW, warmup_cosine
from repro.training.trainer import (Trainer, evaluate,
                                    finetune_cache_conditioned, finetune_full,
                                    pretrain_batches)

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=4, d_model=128,
                   n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=64,
                   dtype="float32")


def train_models(domain="copy", *, pretrain_steps=600, ft_steps=600,
                 batch=48, lr=3e-3, seed=0, cfg=TINY, log_every=0):
    spec = D.TaskSpec(domain=domain, n_symbols=8, prompt_len=10, vocab=64)
    base = init_params(cfg, jax.random.PRNGKey(seed))
    tr = Trainer(functools.partial(train_loss, cfg, remat=False),
                 AdamW(warmup_cosine(lr, pretrain_steps), weight_decay=0.01))
    mix = D.TaskSpec(domain="mix", n_symbols=8, prompt_len=10, vocab=64)
    base, _ = tr.fit(base, pretrain_batches(cfg, seed, pretrain_steps, batch,
                                            spec=mix), log_every=log_every,
                     tag="pretrain")
    full, _ = finetune_full(cfg, base, domain, seed=seed + 1, steps=ft_steps,
                            batch=batch, lr=lr / 2, spec=spec,
                            log_every=log_every)
    ps, _ = finetune_cache_conditioned(cfg, base, base, domain,
                                       seed=seed + 1, steps=ft_steps,
                                       batch=batch, lr=lr / 2, spec=spec,
                                       log_every=log_every)
    return cfg, spec, base, full, ps


def run(quick=True, domain="copy"):
    steps = (300, 300) if quick else (800, 800)
    cfg, spec, base, full, ps = train_models(domain, pretrain_steps=steps[0],
                                             ft_steps=steps[1])
    ratios = (0.0, 0.25, 0.5, 0.75, 1.0)
    rows = []
    for r in ratios:
        acc_full = evaluate(cfg, full, base, domain, seed=7, share_ratio=r,
                            spec=spec, per_token=True)
        acc_ps = evaluate(cfg, ps, base, domain, seed=7, share_ratio=r,
                          spec=spec, per_token=True)
        rows.append({"ratio": r, "full_ft": acc_full, "prefillshare": acc_ps})
    acc_base = evaluate(cfg, base, base, domain, seed=7, share_ratio=1.0,
                        spec=spec, per_token=True)
    rows.append({"ratio": "base-noft", "full_ft": acc_base,
                 "prefillshare": acc_base})
    return rows


def main(quick=True):
    rows = run(quick=quick)
    print("share_ratio,full_ft_acc,prefillshare_acc")
    for r in rows:
        print(f"{r['ratio']},{r['full_ft']:.3f},{r['prefillshare']:.3f}")
    return rows


if __name__ == "__main__":
    main(quick="--full" not in sys.argv)
