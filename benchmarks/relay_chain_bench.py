"""Relay-KV chain A/B: producer->consumer pipelines with decode-KV reuse.

Workload: N independent two-stage chains, the paper's agent-pipeline
pattern. Per chain, a PRODUCER model generates G tokens from a fresh
prompt, then a CONSUMER model (a different registered model id) is prompted
with ``producer_prompt ++ [first_token] ++ producer_output`` — exactly the
stream the engine publishes at finish. Two engines, identical everything,
except:

  relay_on  — the default: the producer's decode-written pages are adopted
              into the engine-global radix tree at finish, so the consumer's
              prefill starts past the producer's ENTIRE output with a
              zero-copy block-table reference (only the joiner token and
              the sub-page tail are cold).
  relay_off — ``relay=False``: the prefix cache still serves the producer's
              PROMPT pages (published at prefill commit), but every
              generated token is re-prefilled from scratch. The A/B delta
              is therefore precisely the decode-KV relay, not prefix
              caching at large.

Latency is the consumer's TTFT from the streaming ``RequestOutput``
(token-push timestamps, what a client observes). Gates: consumer token
streams bit-identical across modes, relayed-token fraction of the
shareable (generated) portion > 0.5, and — full bench only — consumer p95
TTFT >= 1.5x lower with relay on.

Usage: PYTHONPATH=src python -m benchmarks.relay_chain_bench
       PYTHONPATH=src python benchmarks/relay_chain_bench.py --smoke
       ... [--json PATH]   # write BENCH_serving.json (see bench_json.py)
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

try:
    from bench_json import gate, write_bench_json
except ImportError:
    from benchmarks.bench_json import gate, write_bench_json

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="relay-bench", arch_type="dense", n_layers=3,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=64, dtype="float32")

PAGE = 16
CHAINS = 8
PROMPT_LEN = 64           # page-aligned so the relay share is exactly G
GEN_A = 96                # producer output: the shareable portion
GEN_B = 8


def _pct(xs, q):
    xs = [x for x in xs if x is not None]
    return 1e3 * float(np.percentile(xs, q)) if len(xs) else float("nan")


def _prompts(seed: int, chains: int, prompt_len: int):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(4, 60, size=prompt_len)) for _ in range(chains)]


def _drive(eng: LocalDisaggEngine, prompts, gen_a: int, gen_b: int):
    """Run the chains sequentially; returns ((a_tokens, b_tokens) per chain,
    consumer TTFTs, wall seconds, hit-token stats consumed by the WARMUP).
    The consumer prompt is built from the producer's actual output, so
    relay_on/relay_off drive byte-identical workloads as long as the
    streams agree (asserted by the caller). Warmup is one full throwaway
    chain with the measured lengths, so every chunk/decode shape is
    compiled before the clock starts; its hits are snapshotted and
    subtracted by the caller."""
    warm_p = [int(t) for t in
              np.random.default_rng(997).integers(4, 60, size=len(prompts[0]))]
    wa = eng.generate("planner", warm_p, SamplingParams(max_tokens=gen_a))
    eng.run()
    eng.generate("executor", warm_p + [2] + [int(t) for t in wa.tokens],
                 SamplingParams(max_tokens=gen_b))
    eng.run()
    s0 = eng.stats()
    warm_hits = {k: s0[k] for k in ("relay_hit_tokens", "prefix_hit_tokens")}

    streams, ttfts = [], []
    t0 = time.perf_counter()
    for p in prompts:
        a = eng.generate("planner", p, SamplingParams(max_tokens=gen_a))
        eng.run()
        b_prompt = list(p) + [2] + [int(t) for t in a.tokens]
        b = eng.generate("executor", b_prompt,
                         SamplingParams(max_tokens=gen_b))
        eng.run()
        assert a.finished and b.finished
        streams.append((list(a.tokens), list(b.tokens)))
        ttfts.append(b.ttft)
    wall = time.perf_counter() - t0
    return streams, ttfts, wall, warm_hits


def chain_ab(chains: int = CHAINS, prompt_len: int = PROMPT_LEN,
             gen_a: int = GEN_A, gen_b: int = GEN_B, chunk: int = 32,
             budget: int = 64, seed: int = 0, gate_ttft: bool = True):
    base = init_params(CFG, jax.random.PRNGKey(0))
    prompts = _prompts(seed, chains, prompt_len)

    rows, all_streams = [], []
    for mode, on in (("relay_on", True), ("relay_off", False)):
        eng = LocalDisaggEngine(CFG, base, num_pages=512, page_size=PAGE,
                                chunked=True, chunk_size=chunk,
                                token_budget=budget, relay=on)
        # two DISTINCT model ids sharing the base KV path: the reuse below
        # is cross-model, the producer never serves the consumer's request
        eng.models.register("planner", base)
        eng.models.register("executor", base)
        streams, ttfts, wall, warm = _drive(eng, prompts, gen_a, gen_b)
        s = eng.stats()
        relay_hits = s["relay_hit_tokens"] - warm["relay_hit_tokens"]
        prefix_hits = s["prefix_hit_tokens"] - warm["prefix_hit_tokens"]
        gen_total = sum(len(a) + len(b) for a, b in streams)
        rows.append({
            "mode": mode,
            "ttft_p95_ms": _pct(ttfts, 95),
            "ttft_p50_ms": _pct(ttfts, 50),
            "relay_hit_tokens": relay_hits,
            "relayed_fraction": relay_hits / (chains * gen_a),
            "relay_pages_published": s["relay_pages_published"],
            "prefix_hit_tokens": prefix_hits,
            "tok_s": gen_total / wall,
            "chain_wall_s": wall,
        })
        all_streams.append(streams)

    cols = ["mode", "ttft_p95_ms", "ttft_p50_ms", "relay_hit_tokens",
            "relayed_fraction", "relay_pages_published", "prefix_hit_tokens",
            "tok_s", "chain_wall_s"]
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.2f}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))

    on_row, off_row = rows
    assert all_streams[0] == all_streams[1], \
        "relay changed tokens — decode-KV reuse must be bit-identical"
    assert on_row["relayed_fraction"] > 0.5, on_row
    assert off_row["relay_hit_tokens"] == 0
    assert off_row["prefix_hit_tokens"] > 0, \
        "A/B baseline must still have plain prefix caching on"
    speed = off_row["ttft_p95_ms"] / on_row["ttft_p95_ms"]
    print(f"# {chains} chains x (prompt {prompt_len} -> produce {gen_a} -> "
          f"consume): consumer p95 TTFT {off_row['ttft_p95_ms']:.2f}ms "
          f"relay_off -> {on_row['ttft_p95_ms']:.2f}ms relay_on "
          f"({speed:.2f}x lower), relayed fraction "
          f"{on_row['relayed_fraction']:.2f} of the producers' output, "
          f"outputs bit-identical")
    if gate_ttft:
        assert speed >= 1.5, (
            f"relay did not lower consumer p95 TTFT >= 1.5x ({speed:.2f}x)")
    return rows, speed


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 3 short chains (asserts relayed "
                         "fraction > 0.5 and bit-identical outputs; the "
                         "TTFT gate is reserved for the full bench)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving.json here")
    args = ap.parse_args()
    if args.smoke:
        rows, speed = chain_ab(chains=3, prompt_len=32, gen_a=32, gen_b=4,
                               chunk=16, budget=32, gate_ttft=False)
        if args.json:
            write_bench_json(args.json, "relay_chain_smoke", rows, gates={
                "relayed_fraction": gate(rows[0]["relayed_fraction"], 0.5),
            })
        sys.exit(0)
    rows, speed = chain_ab(chunk=args.chunk, budget=args.budget)
    if args.json:
        write_bench_json(args.json, "relay_chain", rows, gates={
            "consumer_ttft_p95_speedup": gate(speed, 1.5),
            "relayed_fraction": gate(rows[0]["relayed_fraction"], 0.5),
        })
