"""Roofline analysis per (arch × input shape) on the single-pod mesh.

Three terms, per deliverable (g):
  compute    = FLOPs / (chips × 197 TF/s bf16)
  memory     = HBM bytes / (chips × 819 GB/s)
  collective = per-chip collective bytes / (50 GB/s per ICI link)

FLOPs and HBM bytes are analytic (launch/analytic.py — cost_analysis counts
loop bodies once, see EXPERIMENTS.md §Dry-run); collective bytes come from the
loop-aware HLO parse stored by the dry-run; per-chip footprint from
memory_analysis. Emits a markdown table + results/roofline.json and a
calibration file for the serving cost model.
"""
from __future__ import annotations

import json
import os
import sys

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def load_dryrun(path="results/dryrun.jsonl"):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r["arch"], r["shape"], r.get("mesh", "?"))
            recs[key] = r
    return recs


def analyze(dryrun_path="results/dryrun.jsonl", mesh="16x16"):
    sys.path.insert(0, "src")
    from repro.configs.base import ASSIGNED, INPUT_SHAPES, get_config
    from repro.launch.analytic import step_analytic

    recs = load_dryrun(dryrun_path)
    rows = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if "skipped" in r:
                rows.append({"arch": arch, "shape": shape, "skipped": r["skipped"]})
                continue
            if "error" in r:
                rows.append({"arch": arch, "shape": shape, "error": r["error"]})
                continue
            chips = r["chips"]
            a = step_analytic(cfg, shape)
            t_c = a["flops"] / (chips * PEAK)
            t_m = a["hbm_bytes"] / (chips * HBM)
            coll = r["collectives"]["total"]          # per-chip (post-SPMD shapes)
            t_x = coll / LINK
            dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                      key=lambda kv: kv[1])[0]
            rows.append({
                "arch": arch, "shape": shape, "chips": chips,
                "flops": a["flops"], "hbm_bytes": a["hbm_bytes"],
                "coll_bytes_per_chip": coll,
                "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
                "dominant": dom,
                "model_flops": a["model_flops"],
                "useful_ratio": a["model_flops"] / a["flops"],
                "step_s_bound": max(t_c, t_m, t_x),
                "mem_per_chip_gb": (r["memory"]["argument_size_in_bytes"]
                                    + r["memory"]["temp_size_in_bytes"]
                                    + r["memory"]["output_size_in_bytes"]) / 1e9,
                "cost_analysis_flops_bodyonce": r["cost"].get("flops", 0.0),
                "compile_s": r.get("compile_s", 0),
            })
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "useful(6ND/FLOPs) | mem/chip GB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP (sub-quadratic rule) | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['error'][:40]} "
                       f"| | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mem_per_chip_gb']:.2f} |")
    return "\n".join(out)


def write_calibration(rows, path="results/calibration.json"):
    """Per-arch scale factors for the serving cost model."""
    calib = {}
    for r in rows:
        if "error" in r or "skipped" in r:
            continue
        calib.setdefault(r["arch"], {})[r["shape"]] = {
            "step_s_bound": r["step_s_bound"], "chips": r["chips"]}
    with open(path, "w") as f:
        json.dump(calib, f, indent=1)


def main():
    rows = analyze()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    write_calibration(rows)
    print(markdown(rows))
    done = [r for r in rows if "error" not in r and "skipped" not in r]
    print(f"\n{len(done)} combos analyzed, "
          f"{sum(1 for r in rows if 'skipped' in r)} skipped, "
          f"{sum(1 for r in rows if 'error' in r)} errors")


if __name__ == "__main__":
    main()
