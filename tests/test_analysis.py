"""repro.analysis: per-rule positive/negative fixtures, baseline round-trip,
JSON schema, and the CLI failing on a bad fixture tree (the CI contract)."""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis import analyze_paths
from repro.analysis.cli import main as cli_main
from repro.analysis.rules import ALL_RULES, RULES_BY_ID

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_rules(tmp_path, source, name="mod.py", rules=None):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return analyze_paths([name], str(tmp_path), rules or ALL_RULES)


def rule_ids(findings):
    return [f.rule for f in findings]


# ======================================================================
# RPR001 donation-after-use
# ======================================================================

def test_rpr001_positive_donated_arg_read_after_call(tmp_path):
    fs = run_rules(tmp_path, """
        import jax
        _step = jax.jit(_impl, donate_argnums=(0,))

        def go(pool, x):
            state = pool.decode_state()
            out = _step(state, x)
            return state["groups"]
    """)
    assert rule_ids(fs) == ["RPR001"]
    assert "'state'" in fs[0].message and "donated" in fs[0].message


def test_rpr001_positive_handle_into_jitted_step(tmp_path):
    fs = run_rules(tmp_path, """
        def go(self, toks):
            state = self.kvpool.decode_state()
            nxt, new = self._step(state, toks)
            k = state["tail"]
            return nxt
    """)
    assert rule_ids(fs) == ["RPR001"]


def test_rpr001_negative_rebind_clears_donation(tmp_path):
    fs = run_rules(tmp_path, """
        import jax
        _step = jax.jit(_impl, donate_argnums=(0,))

        def go(pool, x):
            state = pool.decode_state()
            state = _step(state, x)
            return state["groups"]
    """)
    assert fs == []


def test_rpr001_negative_undonated_position(tmp_path):
    fs = run_rules(tmp_path, """
        import jax
        _step = jax.jit(_impl, donate_argnums=(0,))

        def go(pool, x):
            state = pool.decode_state()
            out = _step(x, state)
            return x
    """)
    # state sits at position 1, only position 0 is donated; x was donated
    # but is a plain arg rebound nowhere and read -> that IS a finding for x
    assert all(f.rule == "RPR001" for f in fs)
    assert not any("'state'" in f.message for f in fs)


def test_rpr001_conditional_donation_tuple_resolves(tmp_path):
    fs = run_rules(tmp_path, """
        import jax
        _copy = jax.jit(_impl, donate_argnums=(0,) if TPU else ())

        def go(state, s, d):
            new = _copy(state, s, d)
            return state
    """)
    assert rule_ids(fs) == ["RPR001"]


# ======================================================================
# RPR002 refcount-balance
# ======================================================================

def test_rpr002_positive_alloc_without_exception_path(tmp_path):
    fs = run_rules(tmp_path, """
        class Worker:
            def grab(self, n):
                blocks = self.pool.alloc(n)
                self.compute(blocks)
                return blocks
    """)
    assert rule_ids(fs) == ["RPR002"]
    assert "pool.alloc" in fs[0].message


def test_rpr002_negative_release_in_handler(tmp_path):
    fs = run_rules(tmp_path, """
        class Worker:
            def grab(self, n):
                blocks = self.pool.alloc(n)
                try:
                    self.compute(blocks)
                except BaseException:
                    self.pool.drop(blocks)
                    raise
                return blocks
    """)
    assert fs == []


def test_rpr002_negative_no_risky_work_after_acquire(tmp_path):
    fs = run_rules(tmp_path, """
        class Worker:
            def grab(self, n, out):
                blocks = self.pool.alloc(n)
                out.extend(blocks)
                return blocks
    """)
    assert fs == []


def test_rpr002_skips_test_files(tmp_path):
    src = """
        def test_pool(pool):
            blocks = pool.alloc(4)
            pool.do_something_risky(blocks)
    """
    assert run_rules(tmp_path, src, name="mod.py") != []
    assert run_rules(tmp_path, src, name="test_mod.py") == []


# ======================================================================
# RPR003 host-sync-in-hot-path
# ======================================================================

def test_rpr003_positive_all_sync_kinds(tmp_path):
    fs = run_rules(tmp_path, """
        import jax
        import numpy as np

        class ToyScheduler:
            def step(self, x, arr, d):
                jax.block_until_ready(x)
                v = float(arr[0])
                y = np.asarray(d)
                t = x.item()
                return v, y, t
    """)
    assert rule_ids(fs) == ["RPR003"] * 4


def test_rpr003_negative_cold_function_and_cold_class(tmp_path):
    fs = run_rules(tmp_path, """
        import jax
        import numpy as np

        class ToyScheduler:
            def shutdown(self, x):
                jax.block_until_ready(x)       # not a hot function name

        class Summary:
            def step(self, d):
                return np.asarray(d)           # not a hot class / path
    """)
    assert fs == []


# ======================================================================
# RPR004 unbucketed-shape-into-jit
# ======================================================================

def test_rpr004_positive_runtime_len_reaches_jit_shape(tmp_path):
    fs = run_rules(tmp_path, """
        import numpy as np

        class Plane:
            def run(self, seqs):
                npages = max(len(s.bt) for s in seqs)
                bt = np.zeros((4, npages), np.int32)
                return self._step(bt)
    """)
    assert "RPR004" in rule_ids(fs)
    assert any("'npages'" in f.message for f in fs)


def test_rpr004_negative_bucketed(tmp_path):
    fs = run_rules(tmp_path, """
        import numpy as np

        class Plane:
            def run(self, seqs):
                npages = next_pow2(max(len(s.bt) for s in seqs))
                bt = np.zeros((4, npages), np.int32)
                return self._step(toks)
    """)
    assert fs == []


def test_rpr004_negative_len_over_self_attr_is_static(tmp_path):
    fs = run_rules(tmp_path, """
        import numpy as np

        class Plane:
            def run(self, toks):
                m = len(self.model_ids)
                lanes = np.zeros((4, m), np.int32)
                return self._step(lanes)
    """)
    assert fs == []


# ======================================================================
# RPR005 side-effect-in-jit
# ======================================================================

def test_rpr005_positive_self_mutation_and_print(tmp_path):
    fs = run_rules(tmp_path, """
        import jax

        def _impl(self, x):
            self.count += 1
            print(x)
            return x

        stepper = jax.jit(_impl)
    """)
    assert rule_ids(fs) == ["RPR005", "RPR005"]
    assert "self.count" in fs[0].message


def test_rpr005_positive_decorated_and_nested(tmp_path):
    fs = run_rules(tmp_path, """
        import jax, time

        @jax.jit
        def outer(x):
            def inner(y):
                t = time.perf_counter()
                return y
            return inner(x)
    """)
    assert rule_ids(fs) == ["RPR005"]
    assert "time.perf_counter" in fs[0].message


def test_rpr005_negative_unjitted_and_pure(tmp_path):
    fs = run_rules(tmp_path, """
        import jax

        def bookkeeping(self, x):
            self.count += 1          # not traced: fine
            return x

        def _pure(x):
            return x + 1

        stepper = jax.jit(_pure)
    """)
    assert fs == []


# ======================================================================
# RPR006 metrics-instrument-in-step
# ======================================================================

def test_rpr006_positive_instrument_in_step(tmp_path):
    fs = run_rules(tmp_path, """
        class Engine:
            def step(self):
                c = self.registry.counter("tokens", "help")
                c.inc()
    """)
    assert rule_ids(fs) == ["RPR006"]
    assert "hoisted" in fs[0].message


def test_rpr006_negative_instrument_in_init(tmp_path):
    fs = run_rules(tmp_path, """
        class Engine:
            def __init__(self, reg):
                self._c = reg.counter("tokens", "help")

            def step(self):
                self._c.inc()
    """)
    assert fs == []


# ======================================================================
# RPR007 host-materialized-pool-pages
# ======================================================================

def test_rpr007_positive_host_copy_of_pool_pages(tmp_path):
    fs = run_rules(tmp_path, """
        import numpy as np

        def snapshot(kvpool):
            return np.asarray(kvpool.k_groups[0])
    """)
    assert rule_ids(fs) == ["RPR007"]
    assert "swap tier" in fs[0].message


def test_rpr007_positive_device_get_pool_state(tmp_path):
    fs = run_rules(tmp_path, """
        import jax

        def dump(kvpool):
            return jax.device_get(kvpool.pool_state())
    """)
    assert rule_ids(fs) == ["RPR007"]


def test_rpr007_negative_sanctioned_swap_module(tmp_path):
    fs = run_rules(tmp_path, """
        import jax

        def put(kvpool):
            return jax.device_get(kvpool.pool_state())
    """, name="kvcache/swap.py")
    assert fs == []


def test_rpr007_negative_non_pool_asarray(tmp_path):
    fs = run_rules(tmp_path, """
        import numpy as np

        def tokens_of(seq):
            return np.asarray(seq.out, np.int32)
    """)
    assert fs == []


# ======================================================================
# framework: fingerprints, baseline round-trip, JSON schema, CLI
# ======================================================================

BAD_SOURCE = """
class Worker:
    def grab(self, n):
        blocks = self.pool.alloc(n)
        self.compute(blocks)
        return blocks
"""


def test_every_rule_has_id_and_registry_entry():
    ids = [r.rule_id for r in ALL_RULES]
    assert ids == sorted(ids) and len(set(ids)) == len(ids)
    assert set(RULES_BY_ID) == {f"RPR00{i}" for i in range(1, 8)}


def test_fingerprints_stable_across_line_shifts(tmp_path):
    f1 = run_rules(tmp_path, BAD_SOURCE)
    f2 = run_rules(tmp_path, "# a comment\n\n\n" + BAD_SOURCE)
    assert [f.fingerprint for f in f1] == [f.fingerprint for f in f2]
    assert f1[0].line != f2[0].line


def test_syntax_error_files_are_skipped(tmp_path):
    (tmp_path / "broken.py").write_text("def nope(:\n")
    assert analyze_paths(["broken.py"], str(tmp_path), ALL_RULES) == []


def test_cli_baseline_round_trip(tmp_path, capsys):
    (tmp_path / "mod.py").write_text(BAD_SOURCE)
    root = str(tmp_path)
    # dirty tree -> exit 1
    assert cli_main(["mod.py", "--root", root]) == 1
    # accept into baseline -> exit 0
    assert cli_main(["mod.py", "--root", root, "--update-baseline"]) == 0
    assert cli_main(["mod.py", "--root", root]) == 0
    bl = json.loads((tmp_path / ".analysis-baseline.json").read_text())
    assert bl["version"] == 1 and len(bl["entries"]) == 1
    assert bl["entries"][0]["rule"] == "RPR002"
    # inject a NEW violation -> exit 1 again, old one stays baselined
    (tmp_path / "mod2.py").write_text(BAD_SOURCE)
    capsys.readouterr()
    assert cli_main(["mod.py", "mod2.py", "--root", root]) == 1
    out = capsys.readouterr().out
    assert "mod2.py" in out and "mod.py:" not in out


def test_cli_stale_baseline_warns_but_passes(tmp_path, capsys):
    (tmp_path / "mod.py").write_text(BAD_SOURCE)
    root = str(tmp_path)
    assert cli_main(["mod.py", "--root", root, "--update-baseline"]) == 0
    (tmp_path / "mod.py").write_text("x = 1\n")       # finding gone
    capsys.readouterr()
    assert cli_main(["mod.py", "--root", root]) == 0
    assert "stale baseline entry" in capsys.readouterr().err


def test_cli_json_schema(tmp_path, capsys):
    (tmp_path / "mod.py").write_text(BAD_SOURCE)
    cli_main(["mod.py", "--root", str(tmp_path), "--json", "-"])
    out = capsys.readouterr().out
    payload = json.loads(out[:out.rindex("}") + 1])
    assert payload["version"] == 1
    (f,) = payload["findings"]
    assert {"rule", "path", "line", "col", "message", "func", "line_text",
            "fingerprint", "baselined"} <= set(f)
    assert f["rule"] == "RPR002" and f["func"] == "Worker.grab"
    assert payload["summary"]["new"] == 1
    assert payload["summary"]["by_rule"] == {"RPR002": 1}


def test_cli_unknown_rule_and_missing_path(tmp_path):
    assert cli_main(["--root", str(tmp_path), "--rules", "RPR999"]) == 2
    assert cli_main(["nope_dir", "--root", str(tmp_path)]) == 2


def test_cli_subprocess_fails_on_bad_tree(tmp_path):
    """The CI-job contract end to end: module invocation, exit 1 on a tree
    with a violation, exit 0 once baselined."""
    (tmp_path / "bad.py").write_text(BAD_SOURCE)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    cmd = [sys.executable, "-m", "repro.analysis", "bad.py",
           "--root", str(tmp_path)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "RPR002" in r.stdout
    r = subprocess.run(cmd + ["--update-baseline"], env=env,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_repo_is_clean_modulo_checked_in_baseline():
    """The acceptance criterion itself, as a test: the analyzer over the
    real tree reports nothing beyond .analysis-baseline.json."""
    root = os.path.abspath(os.path.join(SRC, ".."))
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "tests",
         "benchmarks", "examples", "--root", root,
         "--baseline", ".analysis-baseline.json"],
        env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
