"""Attention semantics: flash == direct, masks, positions, hypothesis sweeps."""
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.attention import attention

KEY = jax.random.PRNGKey(7)


def _mk(B, Sq, Tk, Hq, Hkv, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(Tk - Sq, Tk, dtype=jnp.int32), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32), (B, Tk))
    return q, k, v, qp, kp


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 7, 32]), st.sampled_from([32, 48]),
       st.sampled_from([(4, 2), (2, 1), (4, 4)]), st.sampled_from([0, 16]),
       st.sampled_from([0.0, 20.0]))
def test_flash_equals_direct(B, Sq, Tk_extra, hh, window, cap):
    Hq, Hkv = hh
    D = 16
    Tk = Sq + Tk_extra
    q, k, v, qp, kp = _mk(B, Sq, Tk, Hq, Hkv, D)
    o_direct = attention(q, k, v, qp, kp, window=window, softcap=cap,
                         force_flash=False)
    o_flash = attention(q, k, v, qp, kp, window=window, softcap=cap,
                        force_flash=True, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(o_direct), np.asarray(o_flash),
                               atol=2e-5, rtol=2e-5)


def test_invalid_slots_ignored():
    """kpos=-1 slots (unwritten cache) must not contribute."""
    B, Sq, Tk, H, D = 1, 1, 8, 2, 16
    q, k, v, qp, kp = _mk(B, Sq, Tk, H, H, D)
    qp = jnp.full((B, Sq), 100, jnp.int32)
    kp_valid = jnp.where(jnp.arange(Tk) < 4, jnp.arange(Tk), -1)[None]
    o1 = attention(q, k, v, qp, kp_valid)
    # same but with garbage in the invalid slots
    k2 = k.at[:, 4:].set(99.0)
    v2 = v.at[:, 4:].set(-99.0)
    o2 = attention(q, k2, v2, qp, kp_valid)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_causality():
    """Future positions must not leak: perturbing token j>i leaves row i fixed."""
    B, S, H, D = 1, 8, 2, 16
    q, k, v, qp, kp = _mk(B, S, S, H, H, D)
    o1 = attention(q, k, v, qp, kp)
    k2 = k.at[:, -1].add(5.0)
    v2 = v.at[:, -1].add(5.0)
    o2 = attention(q, k2, v2, qp, kp)
    np.testing.assert_allclose(np.asarray(o1[:, :-1]), np.asarray(o2[:, :-1]),
                               atol=1e-6)
    assert float(jnp.abs(o1[:, -1] - o2[:, -1]).max()) > 1e-4


def test_sliding_window_bounds():
    """With window w, token i attends exactly to (i-w, i]."""
    B, S, H, D, w = 1, 16, 1, 8, 4
    q, k, v, qp, kp = _mk(B, S, S, H, H, D)
    o1 = attention(q, k, v, qp, kp, window=w)
    # tokens outside every query's window can be arbitrary
    k2 = k.at[:, :S - w - 1].set(7.0)
    v2 = v.at[:, :S - w - 1].set(-7.0)
    o2 = attention(q, k2, v2, qp, kp, window=w)
    np.testing.assert_allclose(np.asarray(o1[:, -1]), np.asarray(o2[:, -1]),
                               atol=1e-6)
