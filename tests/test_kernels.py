"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention, paged_attention
from repro.kernels.ref import ref_flash_prefill, ref_paged_decode

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


FLASH_CASES = [
    # B, Hq, Hkv, S, T, D, window, softcap
    (2, 4, 2, 128, 128, 64, 0, 0.0),
    (1, 8, 8, 256, 256, 128, 0, 0.0),       # MHA
    (1, 8, 1, 192, 192, 64, 0, 0.0),        # MQA, non-pow2 seq
    (2, 4, 2, 128, 128, 64, 64, 0.0),       # sliding window
    (1, 4, 2, 256, 256, 128, 0, 50.0),      # softcap (gemma2)
    (1, 2, 1, 64, 320, 64, 0, 0.0),         # cross-len (cache prefix)
    (1, 4, 4, 96, 96, 32, 32, 30.0),        # window + softcap, odd sizes
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_vs_ref(case, dtype):
    B, Hq, Hkv, S, T, D, win, cap = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    o = flash_attention(q, k, v, window=win, softcap=cap, interpret=True)
    r = ref_flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), window=win,
                          softcap=cap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


PAGED_CASES = [
    # B, Hq, Hkv, D, page, npages, pool
    (3, 8, 2, 64, 16, 8, 40),
    (1, 4, 4, 128, 32, 4, 16),
    (2, 8, 1, 64, 16, 16, 64),    # MQA long table
    (4, 2, 2, 32, 8, 4, 20),
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_vs_ref(case, dtype):
    B, Hq, Hkv, D, page, npages, P = case
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), dtype)
    bt = jax.random.randint(ks[3], (B, npages), 0, P)
    maxlen = page * npages
    ln = jax.random.randint(ks[4], (B,), 1, maxlen + 1).astype(jnp.int32)
    o = paged_attention(q, kp, vp, bt, ln, interpret=True)
    r = ref_paged_decode(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_paged_softcap():
    B, Hq, Hkv, D, page, npages, P = 2, 4, 2, 64, 16, 4, 12
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kp = jax.random.normal(ks[1], (P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (P, page, Hkv, D))
    bt = jax.random.randint(ks[3], (B, npages), 0, P)
    ln = jnp.array([30, 64], jnp.int32)
    o = paged_attention(q, kp, vp, bt, ln, softcap=30.0, interpret=True)
    r = ref_paged_decode(q, kp, vp, bt, ln, softcap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_flash_block_skipping_correct():
    """Whole-block skips (causal/window) must not change results."""
    B, Hq, Hkv, S, D = 1, 2, 1, 512, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    # small blocks -> many fully-masked blocks exercised
    from repro.kernels.flash_prefill import flash_prefill
    o = flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), window=128, block_q=64,
                      block_k=64, interpret=True)
    r = ref_flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), window=128)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


# ----------------------------------------------------------------------
# flash_prefill_paged (chunked prefill straight over the pool)

from repro.kernels.flash_prefill_paged import flash_prefill_paged
from repro.kernels.ops import paged_prefill
from repro.kernels.ref import ref_paged_prefill

PAGED_PREFILL_CASES = [
    # B, Hq, Hkv, D, page, npages, pool, S
    (2, 4, 2, 64, 8, 4, 16, 5),      # chunk boundary mid-page
    (1, 8, 1, 32, 16, 3, 8, 16),     # MQA, chunk == page
    (3, 2, 2, 64, 8, 6, 32, 7),      # MHA, ragged starts
    (1, 4, 2, 128, 16, 2, 8, 1),     # degenerate single-token chunk
]


@pytest.mark.parametrize("case", PAGED_PREFILL_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_paged_vs_ref(case, dtype):
    """Kernel vs the dense (materialized-softmax) reference over gathered
    pages, at per-sequence chunk start positions landing anywhere in a
    page."""
    B, Hq, Hkv, D, page, npages, P, S = case
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), dtype)
    bt = jax.random.randint(ks[3], (B, npages), 0, P)
    max_start = npages * page - S
    st = jax.random.randint(ks[4], (B,), 0, max_start + 1).astype(jnp.int32)
    o = paged_prefill(q, kp, vp, bt, st, interpret=True)   # jit'd wrapper
    r = ref_paged_prefill(q, kp, vp, bt, st)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_prefill_paged_softcap():
    B, Hq, Hkv, D, page, npages, P, S = 2, 4, 2, 64, 8, 4, 12, 6
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    kp = jax.random.normal(ks[1], (P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (P, page, Hkv, D))
    bt = jax.random.randint(ks[3], (B, npages), 0, P)
    st = jnp.array([3, 20], jnp.int32)
    o = flash_prefill_paged(q, kp, vp, bt, st, softcap=30.0, interpret=True)
    r = ref_paged_prefill(q, kp, vp, bt, st, softcap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=2e-5)


def test_flash_prefill_paged_matches_contiguous_flash():
    """Position logic end-to-end vs the DENSE flash reference: contiguous
    KV laid into identity-mapped pages, chunk = the last S positions of a
    causal sequence -> rows S.. of the full dense result."""
    B, Hq, Hkv, D, page, T, S = 1, 4, 2, 8, 8, 64, 24
    ks = jax.random.split(KEY, 3)
    k = jax.random.normal(ks[0], (B, T, Hkv, D))
    v = jax.random.normal(ks[1], (B, T, Hkv, D))
    q_full = jax.random.normal(ks[2], (B, T, Hq, D))
    kp = k.reshape(T // page, page, Hkv, D)
    vp = v.reshape(T // page, page, Hkv, D)
    bt = jnp.arange(T // page, dtype=jnp.int32)[None]
    st = jnp.array([T - S], jnp.int32)
    o = flash_prefill_paged(q_full[:, T - S:], kp, vp, bt, st,
                            interpret=True)
    full = ref_flash_prefill(q_full.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, T - S:]),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------
# paged_write (prefill -> paged pool bridge)

from repro.kernels.paged_write import paged_write
from repro.kernels.ref import ref_paged_write


@pytest.mark.parametrize("case", [
    # B, S, Hkv, D, page, pool
    (3, 64, 2, 32, 16, 24),
    (1, 32, 4, 64, 8, 12),
    (2, 128, 1, 128, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_write_vs_ref(case, dtype):
    B, S, H, D, page, P = case
    npages = S // page
    ks = jax.random.split(KEY, 4)
    nk = jax.random.normal(ks[0], (B, S, H, D), dtype)
    nv = jax.random.normal(ks[1], (B, S, H, D), dtype)
    kp = jax.random.normal(ks[2], (P, page, H, D), dtype)
    vp = jax.random.normal(ks[3], (P, page, H, D), dtype)
    # disjoint page assignment across requests
    perm = np.random.RandomState(0).permutation(P)[: B * npages]
    bt = jnp.asarray(perm.reshape(B, npages), jnp.int32)
    nvalid = jnp.asarray(np.random.RandomState(1).randint(1, npages + 1, B),
                         jnp.int32)
    ko, vo = paged_write(nk, nv, kp, vp, bt, nvalid, interpret=True)
    rk, rv = ref_paged_write(nk, nv, kp, vp, bt, nvalid)
    np.testing.assert_array_equal(np.asarray(ko, np.float32),
                                  np.asarray(rk, np.float32))
    np.testing.assert_array_equal(np.asarray(vo, np.float32),
                                  np.asarray(rv, np.float32))


def test_paged_roundtrip_write_then_read():
    """Pages written by paged_write are read back by paged_decode_attention."""
    B, S, H, D, page, P = 2, 64, 2, 64, 16, 16
    npages = S // page
    ks = jax.random.split(KEY, 3)
    nk = jax.random.normal(ks[0], (B, S, H, D))
    nv = jax.random.normal(ks[1], (B, S, H, D))
    kp = jnp.zeros((P, page, H, D))
    vp = jnp.zeros((P, page, H, D))
    bt = jnp.arange(B * npages, dtype=jnp.int32).reshape(B, npages)
    nvalid = jnp.full((B,), npages, jnp.int32)
    kp, vp = paged_write(nk, nv, kp, vp, bt, nvalid, interpret=True)
    q = jax.random.normal(ks[2], (B, 4, D))
    ln = jnp.full((B,), S, jnp.int32)
    o = paged_attention(q, kp, vp, bt, ln, interpret=True)
    # reference: direct attention against the contiguous new KV
    r = ref_paged_decode(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)
