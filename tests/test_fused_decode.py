"""Fused cross-model decode plane (serving/decode.py): one vmapped jitted
forward per engine step for ALL decode models, bit-identical greedy tokens vs
the per-model dispatch loop, donation-aware pool updates, power-of-two
block-table bucketing, and the page-0 padding sentinel."""
import jax
import numpy as np
import pytest

from repro.configs.base import ATTN, ModelConfig
from repro.kvcache.blocks import BlockPool
from repro.models import init_params
from repro.serving.decode import next_pow2
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="fused-eng", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                  dtype="float32")
# 3 layers over a 2-layer pattern: 1 scanned group + 1 unrolled tail layer,
# so the fused step's row merge covers BOTH pool layouts.
CFG_TAIL = ModelConfig(name="fused-tail", arch_type="dense", n_layers=3,
                       d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                       vocab_size=64, dtype="float32",
                       layer_pattern=(ATTN, ATTN))
PAGE = 8


def _params(cfg, n_models):
    base = init_params(cfg, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(cfg, jax.random.PRNGKey(10 + i))
            for i in range(n_models)}
    return base, decs


def _engine(cfg, base, decs, **kw):
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    return LocalDisaggEngine(cfg, base, decs, **kw)


def _mixed_workload(rng, n_models):
    """Ragged contexts, staggered gen lengths: model 0's sequences finish
    first, so later steps run with a model at ZERO active sequences."""
    jobs = []
    for i in range(2 * n_models):
        mid = f"m{i % n_models}"
        ctx = list(rng.integers(4, 60, size=11 + 5 * i))
        gen = 3 if mid == "m0" else 6 + (i % 2)
        jobs.append((i, ctx, mid, gen))
    return jobs


@pytest.mark.parametrize("cfg", [CFG, CFG_TAIL], ids=["grouped", "with-tail"])
def test_fused_matches_per_model_loop_bitwise(cfg):
    """Greedy tokens from the fused multi-model step == the per-model
    dispatch loop, across mixed-model ragged batches, including steps where
    one model has no active sequences left."""
    base, decs = _params(cfg, 3)
    fused = _engine(cfg, base, decs)                 # fused default on paged
    legacy = _engine(cfg, base, decs, fused=False)
    assert fused.decode_plane is not None and legacy.decode_plane is None

    jobs = _mixed_workload(np.random.default_rng(0), 3)
    f_rids = [fused.submit(sid, ctx, mid, gen) for sid, ctx, mid, gen in jobs]
    l_rids = [legacy.submit(sid, ctx, mid, gen) for sid, ctx, mid, gen in jobs]
    fused.run()
    legacy.run()
    for fr, lr in zip(f_rids, l_rids):
        np.testing.assert_array_equal(fused.result(fr), legacy.result(lr))
    # sanity: the workload really did mix models within single steps
    assert fused.stats.decode_tokens == legacy.stats.decode_tokens
    assert fused.stats.decode_batch_mean > 1.0


def test_one_dispatch_per_step_across_models():
    """The acceptance bar: every engine decode step issues exactly ONE jitted
    forward for all active sequences across all decode models (legacy pays
    one per model per step)."""
    base, decs = _params(CFG, 3)
    rng = np.random.default_rng(1)
    ctxs = [list(rng.integers(4, 60, size=12 + i)) for i in range(3)]

    fused = _engine(CFG, base, decs)
    for sid, ctx in enumerate(ctxs):
        fused.submit(sid, ctx, f"m{sid}", gen_tokens=5)
    fused.run()
    assert fused.stats.decode_dispatches == fused.stats.decode_steps
    assert fused.decode_plane.dispatches == fused.stats.decode_steps

    legacy = _engine(CFG, base, decs, fused=False)
    for sid, ctx in enumerate(ctxs):
        legacy.submit(sid, ctx, f"m{sid}", gen_tokens=5)
    legacy.run()
    # all three models active on every engine step -> 3x the dispatches the
    # fused plane issued for the same schedule
    assert legacy.stats.decode_dispatches == 3 * fused.stats.decode_steps


def test_npages_bucketing_stops_per_page_retraces():
    """Block-table width is bucketed to the next power of two: growing by one
    page WITHIN a bucket reuses the jit trace; only crossing a bucket
    boundary (4 -> 5 pages => bucket 4 -> 8) retraces."""
    assert [next_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 16]
    base, decs = _params(CFG, 1)
    eng = _engine(CFG, base, decs)
    # 23-token prompt -> 3 pages (bucket 4); 9 generated tokens end at
    # pos 32 -> 4 pages, still bucket 4: table growth must not retrace.
    eng.invoke(0, list(range(4, 4 + 23)), "m0", gen_tokens=9)
    assert eng.decode_plane.traces == 1
    # push past 32 tokens -> 5 pages -> bucket 8: exactly one more trace
    eng.submit(0, list(range(4, 4 + 23)) + [5] * 6, "m0", gen_tokens=6)
    eng.run()
    assert eng.decode_plane.traces == 2


def test_pool_donation_pair_is_functional_off_tpu():
    """Off-TPU the fused step's donation is a no-op: the pre-step page
    buffers stay valid and unchanged (pure functional update), while the pool
    absorbs the step's returned buffers."""
    base, decs = _params(CFG, 2)
    eng = _engine(CFG, base, decs)
    r0 = eng.submit(0, list(range(4, 24)), "m0", gen_tokens=1)
    r1 = eng.submit(1, list(range(24, 44)), "m1", gen_tokens=1)
    pre = jax.tree.map(lambda x: np.asarray(x).copy(),
                       eng.kvpool.decode_state())
    pre_refs = eng.kvpool.decode_state()            # live pre-step buffers
    eng.run()
    post = eng.kvpool.decode_state()
    changed = False
    for g in pre["groups"]:
        # the old buffers were not mutated in place...
        np.testing.assert_array_equal(
            np.asarray(pre_refs["groups"][g]["k"]), pre["groups"][g]["k"])
        # ...and the pool now holds freshly-appended rows
        changed |= not np.array_equal(np.asarray(post["groups"][g]["k"]),
                                      pre["groups"][g]["k"])
    assert changed, "decode step appended no KV to the pool"
    assert eng.result(r0).shape == (1,) and eng.result(r1).shape == (1,)


def test_sentinel_page_zero_never_holds_live_kv():
    """Regression for the ragged block-table padding alias: page id 0 is a
    never-allocated sentinel, so zero-padded table slots (shorter sequences
    in a wider batch, fused fake rows) cannot alias live KV. Before the fix,
    the FIRST page the pool handed out was id 0 — exactly the page every
    padded slot pointed at."""
    pool = BlockPool(4, PAGE)
    got = pool.alloc(4)                              # drain the whole pool
    assert 0 not in got and min(got) == 1
    with pytest.raises(ValueError, match="sentinel"):
        pool.ref([0])
    with pytest.raises(ValueError, match="sentinel"):
        pool.drop([0])
    pool.check_invariants()

    base, decs = _params(CFG, 2)
    eng = _engine(CFG, base, decs)
    rng = np.random.default_rng(3)
    # long + short sequences decode in one batch: the short row's table is
    # zero-padded to the long row's (bucketed) width every step
    jobs = [(0, list(rng.integers(4, 60, size=37)), "m0", 5),
            (1, list(rng.integers(4, 60, size=9)), "m1", 5)]
    rids = [eng.submit(*j) for j in jobs]
    eng.run()
    used = set()
    for w in eng.prefill_workers:
        for sc in w.sessions.values():
            used.update(sc.block_table)
    assert 0 not in used
    # physical sentinel row 0 never received a write, on any layer
    for g, a in eng.kvpool.k_groups.items():
        assert not np.asarray(a)[:, 0].any(), f"group {g} wrote sentinel row"
    for i, a in enumerate(eng.kvpool.k_tail):
        assert not np.asarray(a)[0].any(), f"tail layer {i} wrote sentinel row"
    # and the mixed-width batch still decodes exactly like isolated runs
    ref = _engine(CFG, base, decs)
    for rid, job in zip(rids, jobs):
        np.testing.assert_array_equal(eng.result(rid),
                                      ref.invoke(*job[:3], gen_tokens=job[3]))


def test_result_fetch_states():
    """result() keeps the entry (repeat reads OK); pop_result() releases it;
    errors name the rid and its fetch state instead of a bare KeyError."""
    base, decs = _params(CFG, 1)
    eng = _engine(CFG, base, decs)
    rid = eng.submit(0, list(range(4, 20)), "m0", gen_tokens=3)
    with pytest.raises(KeyError, match=f"request {rid}: submitted but not"):
        eng.result(rid)
    eng.run()
    first = eng.result(rid)
    np.testing.assert_array_equal(first, eng.result(rid))   # non-consuming
    np.testing.assert_array_equal(first, eng.pop_result(rid))
    with pytest.raises(KeyError, match="already fetched"):
        eng.result(rid)
    with pytest.raises(KeyError, match="already fetched"):
        eng.pop_result(rid)
    with pytest.raises(KeyError, match="unknown request id"):
        eng.result(999)
