"""Routing policies: locality vs load (paper §3.3 'whenever possible')."""
import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.engine import LocalDisaggEngine
from repro.serving.router import POLICIES, PrefillRouter
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions

CFG = get_config("llama31-8b")


def _run(router_policy, rate=8.0, n=50):
    sessions = make_sessions("react", n_sessions=n, arrival_rate=rate, seed=5)
    sim = Simulator(CFG, ServingConfig(
        mode="prefillshare", max_concurrent=128, chips_per_worker=2,
        hbm_per_worker=32e9, router_policy=router_policy), sessions)
    return sim.run()


def test_unit_pick():
    r = PrefillRouter(4, "pinned")
    assert r.pick(5, 0.0, [9, 0, 0, 0]) == 1         # sticks to home
    r = PrefillRouter(4, "least_loaded")
    assert r.pick(5, 0.0, [9, 5, 0.1, 3]) == 2
    r = PrefillRouter(4, "spillover", spill_threshold_s=0.5)
    assert r.pick(5, 0.0, [0, 0.2, 0, 0]) == 1       # below threshold: home
    assert r.pick(5, 0.0, [0, 9.0, 0, 0]) == 0       # overloaded: spill


def test_backlog_decay_is_invariant_to_pick_frequency():
    """Regression: the issued-work router signal decays with ELAPSED TIME,
    not with how often the router is consulted. The old per-pick halving made
    two bursts a second apart see completely different backlogs depending on
    arrival rate."""
    cfg = ModelConfig(name="router-eng", arch_type="dense", n_layers=1,
                      d_model=16, n_heads=2, n_kv_heads=1, d_ff=32,
                      vocab_size=32, dtype="float32")
    base = init_params(cfg, jax.random.PRNGKey(0))
    eng = LocalDisaggEngine(cfg, base, {}, num_pages=16, page_size=8,
                            n_prefill_workers=2, router_policy="least_loaded")
    w0, w1 = eng.prefill_workers
    t0 = 100.0
    for w in (w0, w1):
        w.last_decay_t = t0
    w0.backlog_s, w1.backlog_s = 0.8, 0.2

    # a burst of picks at ONE instant must not move the signal at all
    for _ in range(50):
        eng._pick_worker(7, now=t0)
    assert (w0.backlog_s, w1.backlog_s) == (0.8, 0.2)

    # advancing the clock decays by 2^(-dt/half_life), regardless of whether
    # the router was consulted once or fifty times in between
    hl = eng.BACKLOG_HALFLIFE_S
    eng._pick_worker(7, now=t0 + hl)
    np.testing.assert_allclose((w0.backlog_s, w1.backlog_s), (0.4, 0.1))
    sparse = w0.backlog_s

    eng2 = LocalDisaggEngine(cfg, base, {}, num_pages=16, page_size=8,
                             n_prefill_workers=2,
                             router_policy="least_loaded")
    eng2.prefill_workers[0].backlog_s = 0.8
    eng2.prefill_workers[1].backlog_s = 0.2
    for w in eng2.prefill_workers:
        w.last_decay_t = t0
    for k in range(1, 51):                      # 50x higher pick rate
        eng2._pick_worker(7, now=t0 + hl * k / 50)
    np.testing.assert_allclose(eng2.prefill_workers[0].backlog_s, sparse)
    # and least_loaded still ranks the workers the same way
    assert eng2._pick_worker(7, now=t0 + hl) is eng2.prefill_workers[1]


def test_policies_complete_and_locality_orders_hit_ratio():
    res = {p: _run(p) for p in POLICIES}
    for p, r in res.items():
        assert r["sessions_done"] == 50, p
    # pinned maximizes prefix locality
    assert res["pinned"]["prefix_hit_ratio"] >= \
        res["least_loaded"]["prefix_hit_ratio"]
    # spillover keeps most of the locality
    assert res["spillover"]["prefix_hit_ratio"] >= \
        res["least_loaded"]["prefix_hit_ratio"]
