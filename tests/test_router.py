"""Routing policies: locality vs load (paper §3.3 'whenever possible')."""
from repro.configs import get_config
from repro.serving.router import POLICIES, PrefillRouter
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions

CFG = get_config("llama31-8b")


def _run(router_policy, rate=8.0, n=50):
    sessions = make_sessions("react", n_sessions=n, arrival_rate=rate, seed=5)
    sim = Simulator(CFG, ServingConfig(
        mode="prefillshare", max_concurrent=128, chips_per_worker=2,
        hbm_per_worker=32e9, router_policy=router_policy), sessions)
    return sim.run()


def test_unit_pick():
    r = PrefillRouter(4, "pinned")
    assert r.pick(5, 0.0, [9, 0, 0, 0]) == 1         # sticks to home
    r = PrefillRouter(4, "least_loaded")
    assert r.pick(5, 0.0, [9, 5, 0.1, 3]) == 2
    r = PrefillRouter(4, "spillover", spill_threshold_s=0.5)
    assert r.pick(5, 0.0, [0, 0.2, 0, 0]) == 1       # below threshold: home
    assert r.pick(5, 0.0, [0, 9.0, 0, 0]) == 0       # overloaded: spill


def test_policies_complete_and_locality_orders_hit_ratio():
    res = {p: _run(p) for p in POLICIES}
    for p, r in res.items():
        assert r["sessions_done"] == 50, p
    # pinned maximizes prefix locality
    assert res["pinned"]["prefix_hit_ratio"] >= \
        res["least_loaded"]["prefix_hit_ratio"]
    # spillover keeps most of the locality
    assert res["spillover"]["prefix_hit_ratio"] >= \
        res["least_loaded"]["prefix_hit_ratio"]
