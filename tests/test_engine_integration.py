"""Integration: the real-JAX disaggregated engine's incremental prefill +
cross-model handoff must produce BIT-IDENTICAL generations to a from-scratch
reference (full prefill of the whole context per invocation)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefillshare import base_prefill
from repro.models import forward, init_params
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="eng", arch_type="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")


def _reference_generate(cfg, base, dec, context, gen_tokens, first=2):
    """Full prefill with base, decode with dec — no reuse anywhere."""
    ctx = jnp.asarray(context)[None]
    n = ctx.shape[1]
    _, cache = base_prefill(cfg, base, ctx, cache_len=n + gen_tokens + 1)
    pos = jnp.array([n], jnp.int32)
    tok = jnp.array([first], jnp.int32)
    out = []
    for _ in range(gen_tokens):
        logits, cache, _ = forward(cfg, dec, tok[:, None], cache=cache, pos=pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
        pos = pos + 1
    return np.asarray(out, np.int32)


def test_engine_matches_reference_across_agents_and_turns():
    key = jax.random.PRNGKey(0)
    base = init_params(CFG, key)
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(3)}
    eng = LocalDisaggEngine(CFG, base, decs, capacity=256)

    rng = np.random.default_rng(0)
    context = list(rng.integers(4, 60, size=24))
    sid = 0
    for turn in range(2):
        for mid in ("m0", "m1", "m2"):
            context += list(rng.integers(4, 60, size=6))   # user/obs delta
            gen = eng.invoke(sid, context, mid, gen_tokens=5)
            ref = _reference_generate(CFG, base, decs[mid], context, 5)
            np.testing.assert_array_equal(gen, ref)
            context += list(gen)                           # append outputs
    # incremental reuse actually happened
    assert eng.stats.prefill_tokens_reused > eng.stats.prefill_tokens_computed
    assert eng.stats.handoffs == 6
    assert eng.stats.hit_ratio > 0.5
    eng.end_session(sid)


def test_engine_prefix_hit_accounting_monotone():
    key = jax.random.PRNGKey(1)
    base = init_params(CFG, key)
    eng = LocalDisaggEngine(CFG, base, {"m": init_params(CFG, key)},
                            capacity=256)
    rng = np.random.default_rng(1)
    ctx = list(rng.integers(4, 60, size=32))
    eng.invoke(0, ctx, "m", gen_tokens=2)
    h0 = eng.stats.hit_ratio
    ctx += list(rng.integers(4, 60, size=8))
    eng.invoke(0, ctx, "m", gen_tokens=2)
    assert eng.stats.hit_ratio > h0
