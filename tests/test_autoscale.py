"""Autoscale policy (serving/autoscale.py): property tests against the pure
``decide`` function (fuzzed invariants), time-domain guards on
``Autoscaler``, and the simulator + engine integrations."""
import math

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serving.autoscale import (AutoscaleConfig, AutoscaleSignals,
                                     Autoscaler, ResizeDecision, decide)
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_diurnal_sessions


def _sig(rng, cfg):
    n_prefill = int(rng.integers(cfg.min_prefill, cfg.max_prefill + 1))
    n_decode = int(rng.integers(cfg.min_decode, cfg.max_decode + 1))
    if cfg.total_budget is not None:    # a budgeted fleet never starts over
        n_decode = max(cfg.min_decode,
                       min(n_decode, cfg.total_budget - n_prefill))
    return AutoscaleSignals(
        prefill_backlog_tokens=int(rng.integers(0, 20_000)),
        prefill_backlog_s=float(rng.exponential(0.5)),
        decode_occupancy=float(rng.uniform(0, 1.5)),
        free_page_frac=float(rng.uniform(0, 1)),
        ttft_p95_s=(float("nan") if rng.random() < 0.2
                    else float(rng.exponential(0.5))),
        itl_p95_s=(float("nan") if rng.random() < 0.2
                   else float(rng.exponential(0.05))),
        n_prefill=n_prefill,
        n_decode=n_decode,
        inflight_decode=int(rng.integers(0, 2 * n_decode * cfg.decode_slots)))


CONFIGS = [
    AutoscaleConfig(),                                     # cloud-elastic
    AutoscaleConfig(total_budget=8, min_prefill=2, max_prefill=6,
                    min_decode=2, max_decode=6, decode_slots=16),
    AutoscaleConfig(total_budget=4, min_prefill=1, max_prefill=3,
                    min_decode=1, max_decode=3, ttft_target_s=0.2),
]


@pytest.mark.parametrize("cfg", CONFIGS)
def test_decide_invariants_fuzz(cfg):
    """For any signal sample: at most one worker of movement per pool, the
    [min, max] bands hold, decode never shrinks below in-flight demand, and
    a budgeted fleet never exceeds its budget."""
    rng = np.random.default_rng(0)
    for _ in range(3000):
        sig = _sig(rng, cfg)
        d = decide(cfg, sig)
        assert d.prefill_delta in (-1, 0, 1) and d.decode_delta in (-1, 0, 1)
        n_pre = sig.n_prefill + d.prefill_delta
        n_dec = sig.n_decode + d.decode_delta
        assert cfg.min_prefill <= n_pre <= cfg.max_prefill
        assert cfg.min_decode <= n_dec <= cfg.max_decode
        if d.decode_delta < 0:       # never scale below in-flight demand
            assert n_dec * cfg.decode_slots >= sig.inflight_decode
        if cfg.total_budget is not None:
            assert n_pre + n_dec <= cfg.total_budget
            if sig.n_prefill + sig.n_decode == cfg.total_budget:
                # at budget every move is a funded (+1,-1) shift
                assert d.prefill_delta + d.decode_delta == 0


def test_decide_pure_and_deterministic():
    sig = AutoscaleSignals(5000, 2.0, 0.5, 0.5, 0.3, 0.02, 2, 2, 10)
    cfg = AutoscaleConfig()
    assert decide(cfg, sig) == decide(cfg, sig)


def test_converges_under_constant_load():
    """Closed loop against a synthetic plant: per-worker backlog scales
    inversely with prefill workers, occupancy inversely with decode slots.
    From any start the loop must reach a fixed point — and hold it (no
    oscillation under constant signals)."""
    cfg = AutoscaleConfig(min_prefill=1, max_prefill=8, min_decode=1,
                          max_decode=8, decode_slots=16)

    def plant(n_pre, n_dec):
        demand = 48                                # constant decode demand
        return AutoscaleSignals(
            prefill_backlog_tokens=4000,
            prefill_backlog_s=2.4,                  # 2.4s total backlog
            decode_occupancy=demand / (n_dec * cfg.decode_slots),
            free_page_frac=min(1.0, 0.25 * n_dec),
            ttft_p95_s=0.5, itl_p95_s=0.02,
            n_prefill=n_pre, n_decode=n_dec, inflight_decode=demand)

    for start in ((1, 1), (8, 8), (1, 8), (8, 1)):
        n_pre, n_dec = start
        path = [(n_pre, n_dec)]
        for _ in range(64):
            d = decide(cfg, plant(n_pre, n_dec))
            if not d:
                break
            n_pre += d.prefill_delta
            n_dec += d.decode_delta
            path.append((n_pre, n_dec))
        fixed = (n_pre, n_dec)
        # fixed point reached and HELD for a further 10 evaluations
        for _ in range(10):
            assert not decide(cfg, plant(*fixed)), (start, path)
        # it resolved the pressure: backlog healthy band, occupancy < high
        sig = plant(*fixed)
        assert sig.prefill_backlog_s / fixed[0] <= cfg.backlog_high_s
        assert sig.decode_occupancy < cfg.occupancy_high


def test_budget_regime_fills_then_shifts():
    cfg = AutoscaleConfig(total_budget=8, min_prefill=1, max_prefill=7,
                          min_decode=1, max_decode=7, decode_slots=16)
    idle = dict(prefill_backlog_tokens=0, prefill_backlog_s=0.0,
                decode_occupancy=0.1, free_page_frac=0.9,
                ttft_p95_s=float("nan"), itl_p95_s=float("nan"),
                inflight_decode=0)
    # under budget: grow (deploy idle hardware) even with no pressure
    d = decide(cfg, AutoscaleSignals(n_prefill=2, n_decode=2, **idle))
    assert d and d.prefill_delta + d.decode_delta == 1
    # at budget, idle: hold — pure shrink never fires on a fixed fleet
    assert not decide(cfg, AutoscaleSignals(n_prefill=4, n_decode=4, **idle))
    # at budget, decode pressed: funded shift from prefill
    pressed = dict(idle, decode_occupancy=0.95, free_page_frac=0.05)
    d = decide(cfg, AutoscaleSignals(n_prefill=4, n_decode=4, **pressed))
    assert (d.prefill_delta, d.decode_delta) == (-1, +1)
    # at budget, prefill backlogged: funded shift from decode
    backlogged = dict(idle, prefill_backlog_s=10.0)
    d = decide(cfg, AutoscaleSignals(n_prefill=4, n_decode=4, **backlogged))
    assert (d.prefill_delta, d.decode_delta) == (+1, -1)
    # both pressed at budget: held (no thrash between the two shifts)
    both = dict(pressed, prefill_backlog_s=10.0)
    assert not decide(cfg, AutoscaleSignals(n_prefill=4, n_decode=4, **both))


def test_ttft_attribution_nets_out_decode_itl():
    """A decode-side ITL blowup inflates TTFT too; the policy must judge
    prefill by TTFT net of the decode step, or it would shift workers in
    exactly the wrong direction during decode stalls."""
    cfg = AutoscaleConfig(total_budget=8, min_prefill=1, max_prefill=7,
                          min_decode=1, max_decode=7, decode_slots=16,
                          ttft_target_s=0.3)
    # TTFT 2.0s, but 1.9s of it is one decode step: queue_ttft=0.1 < target,
    # decode pressed -> the shift goes TOWARD decode
    sig = AutoscaleSignals(prefill_backlog_tokens=10, prefill_backlog_s=0.01,
                           decode_occupancy=0.95, free_page_frac=0.05,
                           ttft_p95_s=2.0, itl_p95_s=1.9,
                           n_prefill=4, n_decode=4, inflight_decode=40)
    d = decide(cfg, sig)
    assert (d.prefill_delta, d.decode_delta) == (-1, +1)


def test_autoscaler_interval_and_cooldown():
    cfg = AutoscaleConfig(interval_s=1.0, cooldown_intervals=2,
                          shrink_patience=1)
    sc = Autoscaler(cfg)
    grow = AutoscaleSignals(0, 10.0, 0.5, 0.9, float("nan"), float("nan"),
                            1, 1, 0)
    d = sc.tick(grow, now=0.0)
    assert d.prefill_delta == +1 and sc.decisions == [d]
    # cooldown: (1 + cooldown_intervals) * interval_s = 3s hold
    assert not sc.tick(grow, now=1.0)
    assert not sc.tick(grow, now=2.9)
    assert sc.tick(grow, now=3.0).prefill_delta == +1
    # plain interval gate when nothing was applied
    idle = AutoscaleSignals(0, 0.0, 0.5, 0.9, float("nan"), float("nan"),
                            1, 1, 0)
    sc2 = Autoscaler(AutoscaleConfig(interval_s=1.0, shrink_patience=1))
    assert not sc2.tick(idle, now=0.0)
    assert "interval" in sc2.tick(idle, now=0.5).reason


def test_autoscaler_shrink_patience_debounce():
    """Pure shrinks need shrink_patience consecutive votes; grows reset the
    run (an instantaneous backlog sampled between bursts reads as idle)."""
    cfg = AutoscaleConfig(interval_s=1.0, cooldown_intervals=0,
                          shrink_patience=3)
    sc = Autoscaler(cfg)
    idle = AutoscaleSignals(0, 0.0, 0.05, 0.9, float("nan"), float("nan"),
                            4, 1, 0)      # prefill idle -> shrink vote
    assert "shrink vote" in sc.tick(idle, now=0.0).reason
    assert "shrink vote" in sc.tick(idle, now=1.0).reason
    d = sc.tick(idle, now=2.0)            # third consecutive vote applies
    assert d.prefill_delta == -1
    # a grow between votes resets the run
    sc = Autoscaler(cfg)
    grow = AutoscaleSignals(0, 10.0, 0.5, 0.9, float("nan"), float("nan"),
                            1, 1, 0)
    assert "shrink vote" in sc.tick(idle, now=0.0).reason
    assert sc.tick(grow, now=1.0).prefill_delta == +1
    assert "shrink vote" in sc.tick(idle, now=2.0).reason   # vote 1 again


def test_resize_decision_bool():
    assert not ResizeDecision()
    assert ResizeDecision(prefill_delta=1)
    assert ResizeDecision(decode_delta=-1)


# ----------------------------------------------------------------------
# simulator integration


def test_simulator_autoscale_resizes_and_respects_budget():
    """The diurnal scenario drives real resizes; every applied decision
    keeps the fleet exactly at budget, and the split actually moves."""
    cfg = get_config("internlm2-1.8b")
    ac = AutoscaleConfig(min_prefill=2, max_prefill=6, min_decode=2,
                         max_decode=6, decode_slots=24, total_budget=8,
                         interval_s=0.25, cooldown_intervals=0,
                         backlog_high_s=0.45, backlog_low_s=0.01,
                         free_page_low=0.35)
    sessions = make_diurnal_sessions(n_sessions=24, arrival_rate=5.0,
                                     seed=0, phase_gap_s=8.0)
    sc = ServingConfig(mode="prefillshare", n_prefill_workers=4,
                       n_decode_workers=4, max_concurrent=96,
                       chips_per_worker=1, hbm_per_worker=8e9,
                       b2_policy="backpressure", prefill_chunk_tokens=256,
                       max_decode_batch=16, autoscale=ac)
    sim = Simulator(cfg, sc, sessions)
    r = sim.run()
    assert r["resize_events"] > 0
    assert (r["final_prefill_workers"] + r["final_decode_workers"]
            == ac.total_budget)
    for d in sim.autoscaler.decisions:
        assert d.prefill_delta + d.decode_delta == 0    # funded shifts only
    assert math.isfinite(r["p95_ttft_s"])


# ----------------------------------------------------------------------
# real-engine integration


def test_engine_autoscale_grows_prefill_pool_tokens_unchanged():
    """Step-boundary wiring on the REAL engine: a long-prompt burst under an
    aggressive config grows the prefill pool mid-run (new workers share the
    page pool + radix tree and become routable immediately), applied moves
    land on ``engine_autoscale_decisions_total`` — and the token streams are
    bit-identical to a fixed-fleet run: elasticity changes capacity, never
    the output."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.serving.api import SamplingParams
    from repro.serving.engine import LocalDisaggEngine

    mcfg = ModelConfig(name="autoscale-eng", arch_type="dense", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                       vocab_size=64, dtype="float32")
    params = init_params(mcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    ctxs = [list(rng.integers(4, 60, size=48 + i)) for i in range(6)]

    ac = AutoscaleConfig(interval_s=0.0, cooldown_intervals=0,
                         backlog_high_s=1e-4, shrink_patience=10_000)
    streams = []
    for autoscale in (ac, None):
        eng = LocalDisaggEngine(mcfg, params, num_pages=256, page_size=8,
                                chunked=True, chunk_size=8, token_budget=32,
                                autoscale=autoscale)
        eng.models.register("m0", init_params(mcfg, jax.random.PRNGKey(7)))
        outs = [eng.generate("m0", c, SamplingParams(max_tokens=4))
                for c in ctxs]
        eng.run()
        streams.append([list(o.tokens) for o in outs])
        if autoscale is not None:
            assert len(eng.prefill_workers) > 1        # the pool actually grew
            assert eng.router.n == len(eng.prefill_workers)
            assert eng._autoscaler.decisions            # tick applied resizes
            assert eng._c_autoscale.value >= 1
    assert streams[0] == streams[1]
