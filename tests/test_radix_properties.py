"""Property tests for ``PrefixIndex``: random interleavings of
insert/match/remove_block/lru_leaves hold ``check_invariants()`` and never
surface an evicted block id.

Runs only where hypothesis is installed (it is an optional dev dependency,
not shipped in the serving image); tests/test_prefix_global.py carries a
seeded-random variant of the same interleaving that always runs.
"""
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kvcache.radix import PrefixIndex  # noqa: E402
from repro.kvcache.sanitize import check_index  # noqa: E402

BS = 4

_tokens = st.lists(st.integers(0, 2), min_size=0, max_size=6 * BS)
_ops = st.lists(
    st.one_of(
        st.tuples(st.just("insert"), _tokens),
        st.tuples(st.just("match"), _tokens),
        st.tuples(st.just("remove"), st.integers(1, 80)),
        st.tuples(st.just("lru"), st.integers(0, 8)),
    ),
    max_size=80,
)


def _subtree_bids(node):
    out, stack = [], [node]
    while stack:
        n = stack.pop()
        out.append(n.block_id)
        stack.extend(n.children.values())
    return out


@settings(max_examples=80, deadline=None)
@given(_ops)
def test_random_interleavings_hold_invariants(ops):
    """Model-based check: a dict of token-chain -> block id mirrors the tree
    exactly (insert is first-writer-wins; remove_block drops the whole
    subtree), so match results are predicted, never stale, and
    check_invariants() holds after every operation."""
    idx = PrefixIndex(BS)
    chains: dict[tuple, int] = {}     # full token-prefix -> owning block id
    evicted: set[int] = set()
    next_bid = 1

    for op, arg in ops:
        if op == "insert":
            toks = arg
            nb = len(toks) // BS
            bids = list(range(next_bid, next_bid + nb))
            next_bid += nb
            idx.insert(toks, bids)
            for i, bid in enumerate(bids):
                # first registration of a chain wins; later inserts of the
                # same content reuse the existing node
                chains.setdefault(tuple(toks[:(i + 1) * BS]), bid)
        elif op == "match":
            got, n = idx.match(arg)
            assert n == BS * len(got) <= len(arg)
            assert not (set(got) & evicted), "matched an evicted block"
            # the model predicts the exact chain
            want = []
            for i in range(len(arg) // BS):
                bid = chains.get(tuple(arg[:(i + 1) * BS]))
                if bid is None:
                    break
                want.append(bid)
            assert got == want
        elif op == "remove":
            node = idx._by_block.get(arg)
            doomed = set(_subtree_bids(node)) if node is not None else set()
            idx.remove_block(arg)
            evicted |= doomed
            chains = {k: v for k, v in chains.items() if v not in doomed}
            assert all(b not in idx._by_block for b in doomed)
        else:  # lru
            leaves = idx.lru_leaves(arg)
            assert len(leaves) <= arg
            assert not (set(leaves) & evicted)
            assert all(idx._by_block[b].is_leaf for b in leaves)
        idx.check_invariants()
        check_index(idx)  # sanitizer's raising checker composes with fuzzing
        assert len(idx) == len(chains)


@settings(max_examples=40, deadline=None)
@given(_tokens, _tokens)
def test_match_is_longest_common_block_prefix(a, b):
    """After inserting two sequences, matching either returns a chain whose
    length is at least their shared full-block prefix."""
    idx = PrefixIndex(BS)
    idx.insert(a, list(range(1, 1 + len(a) // BS)))
    idx.insert(b, list(range(100, 100 + len(b) // BS)))
    common = 0
    for i in range(min(len(a), len(b)) // BS):
        if a[i * BS:(i + 1) * BS] != b[i * BS:(i + 1) * BS]:
            break
        common += BS
    for seq in (a, b):
        _, n = idx.match(seq)
        assert n == (len(seq) // BS) * BS   # own sequence always fully hits
        assert n >= common
    idx.check_invariants()
