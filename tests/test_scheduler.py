"""Chunked-prefill scheduler: bit-identity vs the unchunked paged path
across chunk sizes, chunk boundaries mid-page, zero-length tails on full
prefix hits, admission/backpressure under PoolExhausted, and priority
ordering."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kvcache.blocks import PoolExhausted
from repro.models import init_params
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="sched-eng", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                  dtype="float32")
PAGE = 8


def _params():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(2)}
    return base, decs


def _engine(base, decs, **kw):
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    return LocalDisaggEngine(CFG, base, decs, **kw)


def _reference_run(base, decs):
    """Greedy outputs from the unchunked paged path (today's behaviour)."""
    eng = _engine(base, decs)
    rng = np.random.default_rng(0)
    ctx = list(rng.integers(4, 60, size=19))
    outs = []
    for mid in ("m0", "m1"):
        ctx += list(rng.integers(4, 60, size=5))
        out = eng.invoke(0, ctx, mid, gen_tokens=4)
        outs.append(out)
        ctx += list(out)
    return outs, eng.stats


@pytest.mark.parametrize("chunk", [3, 5, 8, 64])
def test_chunked_bit_identical_across_chunk_sizes(chunk):
    """Greedy tokens are token-for-token equal to the unchunked paged path
    for every chunk size — including chunk >= prompt length, which
    degenerates to today's whole-tail prefill — across multi-turn context
    growth and two decode models."""
    base, decs = _params()
    ref, ref_stats = _reference_run(base, decs)

    eng = _engine(base, decs, chunked=True, chunk_size=chunk, token_budget=16)
    rng = np.random.default_rng(0)
    ctx = list(rng.integers(4, 60, size=19))
    for mid, want in zip(("m0", "m1"), ref):
        ctx += list(rng.integers(4, 60, size=5))
        got = eng.invoke(0, ctx, mid, gen_tokens=4)
        np.testing.assert_array_equal(got, want)
        ctx += list(got)
    # same accounting as the eager path: chunking changes the schedule,
    # never the amount of compute or reuse
    assert eng.stats.prefill_tokens_computed == ref_stats.prefill_tokens_computed
    assert eng.stats.prefill_tokens_reused == ref_stats.prefill_tokens_reused
    if chunk < 19:
        assert eng.scheduler.stats.chunks > 1
    eng.end_session(0)
    eng.block_pool.check_invariants()


def test_chunk_boundary_mid_page():
    """Chunk boundaries landing mid-page (chunk % page != 0): the next chunk
    keeps appending into the same physical page via the unaligned scatter."""
    base, decs = _params()
    ref = _engine(base, decs)
    rng = np.random.default_rng(3)
    ctx = list(rng.integers(4, 60, size=19))        # pages: 2 full + partial
    want = ref.invoke(0, ctx, "m0", gen_tokens=5)

    eng = _engine(base, decs, chunked=True, chunk_size=6, token_budget=32)
    got = eng.invoke(0, ctx, "m0", gen_tokens=5)    # boundaries at 6,12,18
    np.testing.assert_array_equal(got, want)
    # 19 tokens in 6-token chunks: 6+6+6+1 -> 4 chunks, but only 3 pages
    assert eng.scheduler.stats.chunks == 4
    sess = eng.prefill_workers[0].sessions[0]
    assert len(sess.block_table) == 3
    eng.end_session(0)
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0


def test_zero_length_tail_after_full_prefix_hit():
    """A prompt fully covered by cached pages (page-aligned length) needs
    ZERO prefill chunks: the request goes straight from admission to the
    decode handoff."""
    base, decs = _params()
    ref = _engine(base, decs)
    rng = np.random.default_rng(4)
    ctx = list(rng.integers(4, 60, size=2 * PAGE))  # exactly 2 full pages
    want0 = ref.invoke(0, ctx, "m0", gen_tokens=4)
    want1 = ref.invoke(1, ctx, "m1", gen_tokens=4)

    eng = _engine(base, decs, chunked=True, chunk_size=4, token_budget=32)
    got0 = eng.invoke(0, ctx, "m0", gen_tokens=4)
    np.testing.assert_array_equal(got0, want0)
    computed = eng.stats.prefill_tokens_computed
    chunks = eng.scheduler.stats.chunks

    got1 = eng.invoke(1, ctx, "m1", gen_tokens=4)   # radix full-prefix hit
    np.testing.assert_array_equal(got1, want1)
    assert eng.stats.prefill_tokens_computed == computed   # nothing computed
    assert eng.scheduler.stats.chunks == chunks            # zero-length tail
    assert eng.stats.prefill_tokens_reused >= 2 * PAGE
    eng.end_session(0)
    eng.end_session(1)
    eng.block_pool.check_invariants()


def test_sibling_submit_chunked_fast_path():
    """Two decode models fanning out over one identical context: the second
    request is held until the first commits, then served from the live
    session's pages without recomputing."""
    base, decs = _params()
    ref = _engine(base, decs)
    rng = np.random.default_rng(5)
    ctx = list(rng.integers(4, 60, size=20))
    w0 = ref.invoke(0, ctx, "m0", gen_tokens=3)
    w1 = ref.invoke(0, ctx, "m1", gen_tokens=3)

    eng = _engine(base, decs, chunked=True, chunk_size=8, token_budget=32)
    r0 = eng.submit(0, ctx, "m0", gen_tokens=3)
    r1 = eng.submit(0, ctx, "m1", gen_tokens=3)
    eng.run()
    np.testing.assert_array_equal(eng.result(r0), w0)
    np.testing.assert_array_equal(eng.result(r1), w1)
    assert eng.stats.prefill_tokens_computed == 20         # computed ONCE
    assert eng.stats.prefill_tokens_reused == 20           # sibling reuse
    assert eng.stats.cow_page_copies == 2                  # one clone each
    eng.end_session(0)
    eng.block_pool.check_invariants()


def test_sibling_pages_pinned_across_leader_session_end():
    """The sibling fast path pins the leader session's pages at ADMISSION:
    if the leader session ends before the (possibly deferred) promotion,
    the pages must stay active — not drop to CACHED where another request
    could evict and reuse them."""
    base, decs = _params()
    ref = _engine(base, decs)
    rng = np.random.default_rng(10)
    ctx = list(rng.integers(4, 60, size=2 * PAGE))  # aligned: no CoW clone
    ref.invoke(0, ctx, "m0", gen_tokens=3)
    want = ref.invoke(0, ctx, "m1", gen_tokens=3)

    eng = _engine(base, decs, chunked=True, chunk_size=8, token_budget=32)
    eng.invoke(0, ctx, "m0", gen_tokens=3)          # leader session resident
    rid = eng.submit(0, ctx, "m1", gen_tokens=3)
    eng.scheduler._admit()                          # sibling captured + pinned
    eng.end_session(0)                              # leader lets go
    sib_bt = eng.scheduler.prefilling[0].sibling_bt
    for p in sib_bt:
        assert eng.block_pool.refcount(p) >= 1      # pin holds pages active
    eng.run()
    np.testing.assert_array_equal(eng.result(rid), want)
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0


def test_admission_hard_pool_exhaustion_raises():
    """A prompt the pool can never host fails loudly (no silent spin): the
    scheduler raises PoolExhausted once no step can make progress."""
    base, decs = _params()
    eng = _engine(base, decs, num_pages=2, chunked=True, chunk_size=4,
                  token_budget=32)
    ctx = list(np.random.default_rng(6).integers(4, 60, size=40))  # 5 pages
    eng.submit(0, ctx, "m0", gen_tokens=2)
    with pytest.raises(PoolExhausted):
        eng.run()


def test_backpressure_holds_request_until_decode_frees_pages():
    """Admission under PoolExhausted: a request whose chunk cannot obtain
    pages is HELD (its computed pages stay put) and completes once the
    running decode finishes and releases its private pages."""
    base, decs = _params()
    ref = _engine(base, decs)
    rng = np.random.default_rng(7)
    ctx_a = list(rng.integers(4, 60, size=18))
    ctx_b = list(rng.integers(4, 60, size=18))
    want_a = ref.invoke(0, ctx_a, "m0", gen_tokens=10)
    want_b = ref.invoke(1, ctx_b, "m1", gen_tokens=10)

    # pool sized so both sessions fit resident, but NOT both prefills plus
    # the first request's decode growth at once -> request B must stall
    eng = _engine(base, decs, num_pages=9, chunked=True, chunk_size=6,
                  token_budget=8)
    ra = eng.submit(0, ctx_a, "m0", gen_tokens=10)
    rb = eng.submit(1, ctx_b, "m1", gen_tokens=10)
    eng.run()
    np.testing.assert_array_equal(eng.result(ra), want_a)
    np.testing.assert_array_equal(eng.result(rb), want_b)
    assert eng.scheduler.stats.stalls > 0
    eng.end_session(0)
    eng.end_session(1)
    eng.block_pool.check_invariants()


def test_priority_policy_schedules_high_priority_first():
    """Under the priority policy a late-arriving high-priority request
    finishes prefill before an earlier low-priority long prompt."""
    base, decs = _params()
    eng = _engine(base, decs, chunked=True, chunk_size=8, token_budget=8,
                  sched_policy="priority")
    rng = np.random.default_rng(8)
    long_ctx = list(rng.integers(4, 60, size=48))
    short_ctx = list(rng.integers(4, 60, size=16))
    r_low = eng.submit(0, long_ctx, "m0", gen_tokens=2, priority=0)
    r_high = eng.submit(1, short_ctx, "m1", gen_tokens=2, priority=5)
    eng.run()
    assert eng.scheduler.promoted.index(r_high) < \
        eng.scheduler.promoted.index(r_low)
    eng.result(r_low), eng.result(r_high)


def test_equal_length_chunks_batch_into_one_forward():
    """Chunks of the same length from different requests run as ONE batched
    base-model forward (max_prefill_batch > 1), with outputs unchanged."""
    base, decs = _params()
    ref = _engine(base, decs)
    rng = np.random.default_rng(9)
    ctxs = [list(rng.integers(4, 60, size=24)) for _ in range(3)]
    wants = [ref.invoke(sid, c, "m0", gen_tokens=3)
             for sid, c in enumerate(ctxs)]

    eng = _engine(base, decs, chunked=True, chunk_size=8, token_budget=64)
    rids = [eng.submit(sid, c, "m0", gen_tokens=3)
            for sid, c in enumerate(ctxs)]
    eng.run()
    for rid, want in zip(rids, wants):
        np.testing.assert_array_equal(eng.result(rid), want)
    assert eng.scheduler.stats.max_prefill_batch >= 2
