"""Beyond-paper: LoRA decode modules with cache-conditioned FT."""
import functools

import jax
import pytest
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import (LoRAPair, cache_conditioned_lora_loss,
                             lora_apply, lora_init, lora_param_count,
                             stack_lora_params, stack_params)
from repro.models import init_params
from repro.training import data as D
from repro.training.optim import AdamW
from repro.training.trainer import evaluate

CFG = ModelConfig(name="lora-t", arch_type="dense", n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=64,
                  dtype="float32")


def test_lora_init_targets_and_identity():
    base = init_params(CFG, jax.random.PRNGKey(0))
    lora = lora_init(jax.random.PRNGKey(1), base, rank=4)
    n_lora = lora_param_count(lora)
    n_base = sum(x.size for x in jax.tree.leaves(base))
    assert 0 < n_lora < 0.1 * n_base            # parameter-efficient
    # B = 0 at init -> merge is an exact identity
    merged = lora_apply(base, lora, rank=4)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_real_param_subtree_named_a_b_is_not_an_adapter():
    """Regression: adapter pairs are a DEDICATED type (``LoRAPair``), not a
    bare two-key dict — a genuine param subtree that happens to use keys
    "A"/"B" must flow through lora_init/lora_apply untouched. The old
    ``is_leaf: set(x) == {"A", "B"}`` heuristic swallowed such a base
    subtree whole and crashed (or corrupted) the merge."""
    key = jax.random.PRNGKey(0)
    base = {
        "wq": jax.random.normal(key, (8, 8)),
        # a REAL parameter subtree whose keys collide with the old adapter
        # encoding (e.g. a factored embedding named A/B)
        "factored": {"A": jax.random.normal(jax.random.fold_in(key, 1), (8, 4)),
                     "B": jax.random.normal(jax.random.fold_in(key, 2), (4, 8))},
    }
    lora = lora_init(jax.random.PRNGKey(1), base, rank=2, targets=("wq",))
    # the collision subtree got NO adapters (its leaves are named A/B, not wq)
    assert lora["factored"] == {"A": None, "B": None}
    assert isinstance(lora["wq"], LoRAPair)
    merged = lora_apply(base, lora, rank=2)           # must not misclassify
    np.testing.assert_array_equal(np.asarray(merged["factored"]["A"]),
                                  np.asarray(base["factored"]["A"]))
    np.testing.assert_array_equal(np.asarray(merged["factored"]["B"]),
                                  np.asarray(base["factored"]["B"]))
    # B=0 at init -> wq is still the exact identity too
    np.testing.assert_array_equal(np.asarray(merged["wq"]),
                                  np.asarray(base["wq"]))
    # and a nonzero adapter changes ONLY its target
    hot = jax.tree_util.tree_map(
        lambda x: x, lora, is_leaf=lambda x: x is None or isinstance(x, LoRAPair))
    hot["wq"] = LoRAPair(lora["wq"].A, jnp.ones_like(lora["wq"].B))
    merged2 = lora_apply(base, hot, rank=2)
    assert not np.array_equal(np.asarray(merged2["wq"]), np.asarray(base["wq"]))
    np.testing.assert_array_equal(np.asarray(merged2["factored"]["A"]),
                                  np.asarray(base["factored"]["A"]))


def test_stack_params_model_axis():
    """The fused decode plane's layout: N structurally-identical pytrees
    stack leaf-wise on a NEW leading model axis, and slicing lane m back out
    recovers model m's params bit-for-bit."""
    ps = [init_params(CFG, jax.random.PRNGKey(s)) for s in range(3)]
    stacked = stack_params(ps)
    for leaf, l0 in zip(jax.tree.leaves(stacked), jax.tree.leaves(ps[0])):
        assert leaf.shape == (3,) + l0.shape
    for m, p in enumerate(ps):
        for leaf, orig in zip(jax.tree.leaves(stacked), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(leaf[m]), np.asarray(orig))


def test_stack_lora_params_preserves_none_and_merge():
    """Adapter stacking keeps untargeted leaves None, and a stacked slice
    merges exactly like the per-model adapter it came from."""
    base = init_params(CFG, jax.random.PRNGKey(0))
    loras = [lora_init(jax.random.PRNGKey(10 + s), base, rank=4)
             for s in range(2)]
    stacked = stack_lora_params(loras)
    flat_s = jax.tree.leaves(stacked, is_leaf=lambda x: x is None)
    flat_0 = jax.tree.leaves(loras[0], is_leaf=lambda x: x is None)
    assert [x is None for x in flat_s] == [x is None for x in flat_0]
    for m in range(2):
        sl = jax.tree.map(lambda x: None if x is None else x[m], stacked,
                          is_leaf=lambda x: x is None)
        a = lora_apply(base, sl, rank=4)
        b = lora_apply(base, loras[m], rank=4)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_lora_grads_only_adapters():
    base = init_params(CFG, jax.random.PRNGKey(0))
    lora = lora_init(jax.random.PRNGKey(1), base, rank=4)
    b = D.make_batch(np.random.default_rng(0),
                     D.TaskSpec(domain="copy", n_symbols=8, prompt_len=8), 4)

    def lf(lp):
        loss, _ = cache_conditioned_lora_loss(
            CFG, lp, base, jnp.asarray(b.prompt), jnp.asarray(b.target_in),
            jnp.asarray(b.target_out), jnp.asarray(b.target_mask), rank=4)
        return loss

    g = jax.grad(lf)(lora)
    gnorm = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert gnorm > 0

    def lf_base(bp):
        loss, _ = cache_conditioned_lora_loss(
            CFG, lora, bp, jnp.asarray(b.prompt), jnp.asarray(b.target_in),
            jnp.asarray(b.target_out), jnp.asarray(b.target_mask), rank=4)
        return loss

    gb = jax.grad(lf_base)(base)
    assert sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(gb)) == 0.0


@pytest.mark.xfail(
    strict=False,
    reason="convergence shortfall, fails identically at the seed commit: "
    "the 600-step base pretrain only reaches ~0.18 copy accuracy in this "
    "environment (validated run: base 0.497 -> LoRA 1.000), so the LoRA "
    "fine-tune has no cache-conditioned signal to amplify. Tracking: needs "
    "a retuned pretrain budget/LR for this config, not a serving-side "
    "change; the non-convergence LoRA surfaces stay covered by the other "
    "tests in this file and paged_decode_bench --adapters.")
def test_lora_cache_conditioned_learns():
    """LoRA decode module (rank 16, attn+MLP targets, 19% of params) reaches
    1.0 accuracy from the SHARED base cache (validated config: base acc 0.497
    -> LoRA 1.000; beyond-paper claim, see EXPERIMENTS.md)."""
    from repro.models.model import train_loss
    from repro.training.optim import warmup_cosine
    from repro.training.trainer import Trainer, pretrain_batches

    spec = D.TaskSpec(domain="copy", n_symbols=8, prompt_len=10, vocab=64)
    base = init_params(CFG, jax.random.PRNGKey(0))
    tr = Trainer(functools.partial(train_loss, CFG, remat=False),
                 AdamW(warmup_cosine(3e-3, 600), weight_decay=0.01))
    base, _ = tr.fit(base, pretrain_batches(
        CFG, 0, 600, 48, spec=D.TaskSpec(domain="mix", n_symbols=8,
                                         prompt_len=10, vocab=64)))

    targets = ("wq", "wk", "wv", "wo", "wi", "wu")
    rank = 16
    lora = lora_init(jax.random.PRNGKey(5), base, rank=rank, targets=targets)

    def loss_fn(lp, **kw):
        return cache_conditioned_lora_loss(CFG, lp, base, rank=rank, **kw)

    tr2 = Trainer(loss_fn, AdamW(5e-3, weight_decay=0.0))
    feed = ({"prompt": b.prompt, "target_in": b.target_in,
             "target_out": b.target_out, "target_mask": b.target_mask}
            for b in D.batches(1, spec, 48, 600))
    lora, losses = tr2.fit(lora, feed)

    dec = lora_apply(base, lora, rank=rank)
    acc = evaluate(CFG, dec, base, "copy", seed=9, share_ratio=1.0,
                   spec=spec, per_token=True)
    acc_base = evaluate(CFG, base, base, "copy", seed=9, share_ratio=1.0,
                        spec=spec, per_token=True)
    n_lora = lora_param_count(lora)
    n_base = sum(x.size for x in jax.tree.leaves(base))
    assert n_lora < 0.25 * n_base
    assert acc > 0.9, (acc, acc_base)
    assert acc > acc_base + 0.2
