"""KV cache subsystem: unit + hypothesis property tests on the invariants."""
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.kvcache import (BlockPool, CacheManager, PoolExhausted, PrefixIndex)
from repro.kvcache.sanitize import check_pool

CFG = get_config("llama31-8b")


# ----------------------------------------------------------------------
# BlockPool


def test_pool_alloc_free_cycle():
    p = BlockPool(8, 4)
    a = p.alloc(5)
    assert p.active_count == 5
    p.unref(a)
    assert p.free_count == 8          # cached blocks still reusable
    b = p.alloc(8)                    # evicts cached
    assert len(b) == 8
    with pytest.raises(PoolExhausted):
        p.alloc(1)
    p.check_invariants()


def test_pool_ref_shared_blocks():
    p = BlockPool(4, 4)
    a = p.alloc(2)
    p.unref(a)            # cached
    p.ref(a)              # prefix hit re-pins
    assert p.refcount(a[0]) == 1
    p.ref(a)              # second request shares
    assert p.refcount(a[0]) == 2
    p.unref(a)
    p.unref(a)
    p.check_invariants()


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "unref", "ref", "touch"]),
                          st.integers(0, 6)), max_size=60))
def test_pool_invariants_random_ops(ops):
    p = BlockPool(8, 4)
    held = []
    cached = []
    for op, n in ops:
        if op == "alloc":
            try:
                blocks = p.alloc(n % 4 + 1)
                held.append(blocks)
            except PoolExhausted:
                pass
        elif op == "unref" and held:
            blocks = held.pop(n % len(held))
            p.unref(blocks)
            cached.append(blocks)
        elif op == "ref" and cached:
            blocks = cached[n % len(cached)]
            try:
                p.ref(blocks)
                held.append(blocks)
                cached.remove(blocks)
            except ValueError:
                pass                  # evicted meanwhile — legal
        elif op == "touch" and cached:
            p.touch(cached[n % len(cached)])
        p.check_invariants()
        check_pool(p)     # sanitizer's raising checker composes with fuzzing


# ----------------------------------------------------------------------
# PrefixIndex


def test_radix_basic_match():
    ix = PrefixIndex(4)
    toks = list(range(16))
    ix.insert(toks, [10, 11, 12, 13])
    blocks, n = ix.match(toks)
    assert blocks == [10, 11, 12, 13] and n == 16
    blocks, n = ix.match(toks[:10])           # partial: 2 full blocks
    assert blocks == [10, 11] and n == 8
    blocks, n = ix.match(toks[:8] + [99] * 8)  # diverges after 2 blocks
    assert blocks == [10, 11] and n == 8


def test_radix_eviction_drops_subtree():
    ix = PrefixIndex(4)
    toks = list(range(16))
    ix.insert(toks, [0, 1, 2, 3])
    ix.remove_block(1)           # interior node -> descendants orphaned
    blocks, n = ix.match(toks)
    assert blocks == [0] and n == 4
    ix.check_invariants()


@settings(max_examples=100, deadline=None)
@given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=20),
                min_size=1, max_size=12))
def test_radix_match_equals_naive(seqs):
    """Radix longest-prefix match == brute force over inserted sequences."""
    bs = 2
    ix = PrefixIndex(bs)
    inserted = []
    next_block = [0]

    def blocks_for(tokens):
        n = len(tokens) // bs
        out = list(range(next_block[0], next_block[0] + n))
        next_block[0] += n
        return out

    for s in seqs:
        ix.insert(s, blocks_for(s))
        inserted.append(list(s))
        ix.check_invariants()

    for s in seqs:
        _, matched = ix.match(s)
        best = 0
        for t in inserted:
            common = 0
            for a, b in zip(t, s):
                if a != b:
                    break
                common += 1
            best = max(best, (common // bs) * bs)
        assert matched == best


# ----------------------------------------------------------------------
# CacheManager


def test_manager_prefix_extension():
    m = CacheManager(CFG, num_blocks=32, block_size=4)
    t1 = list(range(16))
    a1 = m.acquire(t1)
    assert a1.cached_tokens == 0
    m.commit(t1, a1)
    m.release(a1)
    a2 = m.acquire(t1 + [50, 51, 52, 53])
    assert a2.cached_tokens == 16      # incremental extension
    m.release(a2)


def test_manager_hit_accounting():
    m = CacheManager(CFG, num_blocks=32, block_size=4)
    t = list(range(16))
    a = m.acquire(t)
    m.commit(t, a)
    m.release(a)
    a = m.acquire(t)
    m.release(a)
    assert m.stats.hit_ratio == pytest.approx(16 / 32)


def test_manager_eviction_under_pressure():
    m = CacheManager(CFG, num_blocks=8, block_size=4)
    for i in range(10):
        t = [100 * i + j for j in range(16)]
        a = m.acquire(t)
        m.commit(t, a)
        m.release(a)
        m.pool.check_invariants()
        m.index.check_invariants()
    assert m.pool.stats.evictions > 0
