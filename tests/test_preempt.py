"""Oversubscription: priority preemption with the host-memory KV swap tier.

Every preempted run must be TOKEN-BIT-IDENTICAL to the same fleet run
without preemption (greedy and seeded), page refcounts must return to
baseline after storms and aborts at every lifecycle stage, and the
sanitizer must census SWAPPED pages as first-class state.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kvcache.sanitize import SanitizerError
from repro.kvcache.swap import HostSwapPool, next_pow2
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="preempt-eng", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab_size=64, dtype="float32")
PAGE = 8
PAGES = 18          # tight: 2 long lo-pri decodes + 2 hi-pri prompts collide
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _build(**kw):
    kw.setdefault("num_pages", PAGES)
    eng = LocalDisaggEngine(CFG, PARAMS, paged=True, page_size=PAGE,
                            chunked=True, **kw)
    eng.models.register("m", PARAMS)
    return eng


def _run_fleet(mode=None, seeded=False, **kw):
    """The contention fleet: two long low-priority decodes fill the pool,
    then two high-priority prompts arrive and need pages NOW."""
    eng = _build(**kw)
    if mode:
        eng.swap.cfg.mode = mode
    sp = dict(temperature=0.8, top_k=8, seed=123) if seeded else {}
    lo = [eng.generate("m", [2 + i] * 9, SamplingParams(max_tokens=40, **sp),
                       priority=0)
          for i in range(2)]
    for _ in range(4):
        eng.step()
    hi = [eng.generate("m", [30 + i] * 17, SamplingParams(max_tokens=6, **sp),
                       priority=5)
          for i in range(2)]
    eng.run()
    return eng, [list(h.result()) for h in lo + hi]


def _start_decode(eng, tokens=None, max_tokens=12, priority=0):
    h = eng.generate("m", tokens or list(range(1, 12)),
                     SamplingParams(max_tokens=max_tokens), priority=priority)
    for _ in range(32):
        eng.step()
        if eng.scheduler.active:
            return h
    raise AssertionError("request never reached decode")


# ======================================================================
# swap tier data plane (kvcache/swap.py)
# ======================================================================

def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def _rows(kvpool, bids):
    """Host copies of the pool rows for ``bids`` (tests are exempt from
    RPR007 — this is exactly what production code must not do)."""
    st = kvpool.pool_state()
    out = []
    for key in ("kg", "vg"):
        for _, a in sorted(st[key].items()):
            out.append(np.asarray(a)[:, list(bids)])
    for key in ("kt", "vt"):
        for a in st[key]:
            out.append(np.asarray(a)[list(bids)])
    return out


def test_host_swap_roundtrip_bit_identical():
    """put -> restore into DIFFERENT device rows reproduces the original
    page KV bit-for-bit across every layer group and tail."""
    eng = _build(num_pages=32)
    _start_decode(eng)
    seq = eng.scheduler.active[0]
    assert seq.private_blocks, "fixture must produce private pages"
    bids = list(seq.private_blocks)
    before = _rows(eng.kvpool, bids)

    host = HostSwapPool()
    nbytes = host.put(eng.kvpool, 999, bids)
    assert nbytes == len(bids) * eng.kvpool.page_bytes
    assert 999 in host and host.entry_pages(999) == len(bids)

    dst = eng.block_pool.alloc(len(bids))
    host.restore(eng.kvpool, 999, list(range(len(bids))), dst)
    after = _rows(eng.kvpool, dst)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    host.pop(999)
    assert len(host) == 0 and host.total_bytes == 0


def test_host_swap_pool_rejects_duplicate_rid():
    eng = _build(num_pages=32)
    _start_decode(eng)
    bids = list(eng.scheduler.active[0].private_blocks)
    host = HostSwapPool()
    host.put(eng.kvpool, 7, bids)
    with pytest.raises(AssertionError, match="already swapped"):
        host.put(eng.kvpool, 7, bids)


# ======================================================================
# priority plumbing (satellite a)
# ======================================================================

def test_priority_param_validation():
    with pytest.raises(ValueError, match="priority must be an int"):
        SamplingParams(priority="high")
    with pytest.raises(ValueError, match="priority must be an int"):
        SamplingParams(priority=True)
    assert SamplingParams(priority=-3).priority == -3


def test_priority_reaches_decode_seq():
    eng = _build()
    _start_decode(eng, priority=3)
    assert eng.scheduler.active[0].priority == 3
    eng.run()

    eng = _build()
    h = eng.generate("m", list(range(1, 12)),
                     SamplingParams(max_tokens=4, priority=2))
    for _ in range(32):
        eng.step()
        if eng.scheduler.active:
            break
    assert eng.scheduler.active[0].priority == 2
    h.result()


def test_engine_flag_validation():
    with pytest.raises(ValueError, match="preempt=True requires the paged"):
        LocalDisaggEngine(CFG, PARAMS, paged=False, preempt=True)
    with pytest.raises(ValueError, match="only safe with preemption armed"):
        _build(overcommit=2.0)


# ======================================================================
# bit-identity: preempted == never-preempted
# ======================================================================

def test_preempt_auto_greedy_bit_identical():
    _, ref = _run_fleet()
    eng, got = _run_fleet(preempt=True, overcommit=2.0, sanitize=True)
    assert got == ref
    assert eng.stats()["preemptions"] >= 1
    assert eng.block_pool.free_count == PAGES          # baseline restored
    assert eng.stats()["pages_swapped"] == 0
    assert eng.stats()["swapped_seqs"] == 0


def test_forced_swap_mode_bit_identical_with_counters():
    _, ref = _run_fleet()
    eng, got = _run_fleet(mode="swap", preempt=True, overcommit=2.0,
                          sanitize=True)
    assert got == ref
    s = eng.stats()
    assert s["preemptions"] >= 1
    assert s["swap_out_pages"] >= 1
    assert s["swap_bytes"] >= eng.kvpool.page_bytes
    assert eng.block_pool.free_count == PAGES
    assert len(eng.swap.host) == 0                     # all entries popped


def test_forced_recompute_mode_bit_identical_with_counters():
    _, ref = _run_fleet()
    eng, got = _run_fleet(mode="recompute", preempt=True, overcommit=2.0,
                          sanitize=True)
    assert got == ref
    s = eng.stats()
    assert s["preemptions"] >= 1
    assert s["recompute_tokens"] >= 1
    assert eng.block_pool.free_count == PAGES


def test_seeded_sampling_bit_identical_both_modes():
    """Sampling keys fold from (seed, absolute position): parking a victim
    must not shift a single draw, in either restore path."""
    _, ref = _run_fleet(seeded=True)
    e_sw, got_sw = _run_fleet(mode="swap", seeded=True, preempt=True,
                              overcommit=2.0, sanitize=True)
    e_rc, got_rc = _run_fleet(mode="recompute", seeded=True, preempt=True,
                              overcommit=2.0, sanitize=True)
    assert got_sw == ref
    assert got_rc == ref
    assert e_sw.stats()["preemptions"] >= 1
    assert e_rc.stats()["preemptions"] >= 1


# ======================================================================
# abort at every lifecycle stage, including swapped-out
# ======================================================================

def _park_one(eng):
    """Drive the fleet until one victim is parked in the swap tier."""
    lo = [eng.generate("m", [2 + i] * 9, SamplingParams(max_tokens=40),
                       priority=0)
          for i in range(2)]
    for _ in range(4):
        eng.step()
    hi = [eng.generate("m", [30 + i] * 17, SamplingParams(max_tokens=6),
                       priority=5)
          for i in range(2)]
    for _ in range(64):
        eng.step()
        if eng.swap.records:
            return lo, hi
    raise AssertionError("no victim was ever parked")


def test_abort_while_swapped_returns_pool_to_baseline():
    eng = _build(preempt=True, overcommit=2.0, sanitize=True)
    eng.swap.cfg.mode = "swap"
    lo, hi = _park_one(eng)
    parked_rid = next(iter(eng.swap.records))
    victim = next(h for h in lo if h.request_id == parked_rid)
    assert eng.stats()["swapped_seqs"] == 1
    assert eng.abort(victim)
    assert victim.finished and victim.finish_reason == "abort"
    assert parked_rid not in eng.swap.records
    assert parked_rid not in eng.swap.host
    eng.run()
    for h in lo + hi:
        if h is not victim:
            h.result()
    assert eng.block_pool.free_count == PAGES
    assert eng.block_pool.swapped_count == 0


def test_abort_every_stage_with_preempt_armed():
    eng = _build(preempt=True, overcommit=2.0, sanitize=True)
    prompt = list(range(1, 12))
    # queued
    h = eng.generate("m", prompt, SamplingParams(max_tokens=4))
    assert eng.abort(h) and h.finish_reason == "abort"
    # mid-prefill
    h = eng.generate("m", prompt, SamplingParams(max_tokens=4))
    eng.step()
    assert eng.abort(h) and h.finish_reason == "abort"
    # decoding
    h = _start_decode(eng, max_tokens=8)
    assert eng.abort(h) and h.finish_reason == "abort"
    eng.run()
    assert eng.block_pool.free_count == PAGES


# ======================================================================
# storm: refcounts to baseline under sustained churn
# ======================================================================

def test_preempt_storm_sanitized_refcounts_baseline():
    """Mixed-priority storm on a tight pool with the sanitizer checking
    every step: everything finishes, nobody thrashes, pool to baseline."""
    eng = _build(preempt=True, overcommit=2.0, sanitize=True)
    rng = np.random.default_rng(0)
    hs = []
    for wave in range(3):
        for i in range(2):
            pr = int(rng.integers(0, 6))
            toks = [int(t) for t in rng.integers(2, 60, size=9)]
            hs.append(eng.generate("m", toks,
                                   SamplingParams(max_tokens=10 + 4 * i),
                                   priority=pr))
        for _ in range(6):
            eng.step()
    eng.run()
    for h in hs:
        h.result()
        assert h.finish_reason == "length"
    assert eng.block_pool.free_count == PAGES
    assert eng.block_pool.swapped_count == 0
    assert len(eng.swap.host) == 0
    # thrash gate: hysteresis bounds per-sequence park/resume churn
    assert all(n <= 4 for n in eng.swap.resume_counts.values())


# ======================================================================
# sanitizer: SWAPPED pages are first-class censused state
# ======================================================================

def test_swapped_page_without_record_names_swap_tier():
    """A page seeded SWAPPED with no owning swap record must trip the step
    census with a diagnostic naming the swap tier as the holder class."""
    eng = _build(sanitize=True)
    _start_decode(eng)
    seq = eng.scheduler.active[0]
    assert seq.private_blocks
    bid = seq.private_blocks[0]
    eng.block_pool.swap_out([bid])          # no HostSwapPool entry: leaked
    with pytest.raises(SanitizerError, match="holder: swap tier"):
        eng.step()


def test_stats_surface_while_parked():
    eng = _build(preempt=True, overcommit=2.0)
    eng.swap.cfg.mode = "swap"
    lo, hi = _park_one(eng)
    s = eng.stats()
    assert s["swapped_seqs"] >= 1
    assert s["pages_swapped"] == eng.block_pool.swapped_count
    assert eng.scheduler.has_work()         # parked victims ARE pending work
    eng.run()
    for h in lo + hi:
        h.result()
    assert eng.block_pool.free_count == PAGES
