"""Optimizer, data pipeline, and checkpoint tests (+ hypothesis properties)."""
import os
import tempfile

import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.training import data as D
from repro.training.checkpoint import load, save
from repro.training.optim import AdamW, apply_updates, warmup_cosine


def test_adamw_minimizes_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = AdamW(0.1, weight_decay=0.0)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_weight_decay_on_matrices_only():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    opt = AdamW(1.0, weight_decay=0.5)
    upd, _ = opt.update(g, opt.init(params), params)
    assert float(jnp.abs(upd["w"]).sum()) > 0     # decayed
    assert float(jnp.abs(upd["b"]).sum()) == 0    # vectors not decayed


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    opt = AdamW(1e-3, grad_clip=1.0)
    upd, _ = opt.update(g, opt.init(params), params)
    assert np.isfinite(np.asarray(upd["w"])).all()


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 100, warmup_ratio=0.1)
    assert float(lr(0)) < float(lr(10))
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) < float(lr(50))


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.ones(3)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save(path, tree, meta={"step": 7})
        restored = load(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


# ----------------------------------------------------------------------
# data pipeline properties


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(["math", "copy", "reverse", "lookup"]),
       st.integers(4, 30), st.integers(4, 12), st.integers(0, 10_000))
def test_answer_is_function_of_prompt(domain, plen, nsym, seed):
    spec = D.TaskSpec(domain=domain, prompt_len=plen, n_symbols=nsym)
    rng1 = np.random.default_rng(seed)
    rng2 = np.random.default_rng(seed)
    p1, a1 = D._gen_one(rng1, spec)
    p2, a2 = D._gen_one(rng2, spec)
    assert (p1 == p2).all() and (a1 == a2).all()
    assert p1.min() >= D.SYM0 and p1.max() < D.SYM0 + nsym


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["math", "copy", "lookup"]), st.integers(1, 8),
       st.integers(0, 1000))
def test_batch_alignment(domain, bs, seed):
    spec = D.TaskSpec(domain=domain, prompt_len=8, n_symbols=6)
    b = D.make_batch(np.random.default_rng(seed), spec, bs)
    assert b.prompt.shape[0] == bs
    # teacher forcing alignment: target_in shifted-right of target_out
    for i in range(bs):
        n = int(b.target_mask[i].sum())
        assert b.target_in[i, 0] == D.SEP
        assert (b.target_in[i, 1:n] == b.target_out[i, : n - 1]).all()
        assert b.target_out[i, n - 1] == D.EOS
        # prompt ends with SEP, starts (after padding) with BOS
        row = b.prompt[i]
        nz = row[row != D.PAD]
        assert nz[0] == D.BOS and nz[-1] == D.SEP


def test_answer_accuracy_metric():
    pred = np.array([[5, 6, 3]])
    tgt = np.array([[5, 6, 3]])
    mask = np.ones((1, 3), np.float32)
    assert D.answer_accuracy(pred, tgt, mask) == 1.0
    pred2 = np.array([[5, 0, 3]])
    assert D.answer_accuracy(pred2, tgt, mask) == 0.0
    mask2 = np.array([[1, 0, 1]], np.float32)   # masked mismatch ignored
    assert D.answer_accuracy(pred2, tgt, mask2) == 1.0
