"""Relay KV: decode-written pages published into the engine-global radix
tree at sequence finish, so a later request from ANY relay-compatible model
whose prompt extends prompt ++ generated tokens starts prefill past the
producer's entire output with a zero-copy block-table reference — and every
relayed token stream is bit-identical to a relay=False run."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.lora import LoRAPair, lora_init
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine
from repro.serving.registry import LoRAAdapter

CFG = ModelConfig(name="relay-eng", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab_size=64, dtype="float32")
PAGE = 8
PROMPT = list(range(1, 21))                      # 2 full pages + a 4-token tail


@pytest.fixture(scope="module")
def base():
    return init_params(CFG, jax.random.PRNGKey(0))


def _relay_engine(base, *, relay=True, **kw):
    """Two full-weight decoders sharing the base KV path: both are
    relay-compatible, so A's decode pages are shareable with B."""
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    eng = LocalDisaggEngine(CFG, base, relay=relay, **kw)
    eng.models.register("a", base)
    eng.models.register("b", base)
    return eng


def _tok(seed, n):
    return [int(t) for t in
            np.random.default_rng(seed).integers(4, 60, size=n)]


def _chain(eng, prompt, a_max=12, b_max=6):
    """The paper's pipeline pattern: B's prompt = A's prompt ++ A's output
    (joined by the default first_token=2, matching the published stream)."""
    a_out = list(eng.generate("a", prompt,
                              SamplingParams(max_tokens=a_max)).result())
    b_prompt = list(prompt) + [2] + [int(t) for t in a_out]
    b_out = list(eng.generate("b", b_prompt,
                              SamplingParams(max_tokens=b_max)).result())
    return a_out, b_prompt, b_out


# ======================================================================
# tentpole headline: chain reuse past A's output, bit-identical to relay off


@pytest.mark.parametrize("chunked", [False, True], ids=["eager", "chunked"])
def test_chain_relay_hits_and_bit_identity(base, chunked):
    """A->B chain under sanitize=True: B's lookup covers every full page of
    the published stream (prompt AND generated tokens), the relay share of
    the hit exceeds half of A's output, and B's tokens are bit-identical to
    a relay=False engine."""
    kw = dict(chunked=chunked, sanitize=True)
    on = _relay_engine(base, **kw)
    a_out, b_prompt, b_on = _chain(on, PROMPT)
    s = on.stats()
    assert s["relay_publishes"] >= 1 and s["relay_pages_published"] >= 1
    # published stream = prompt ++ first0 ++ out[:-1]; B extends it, so the
    # cached prefix reaches past A's ENTIRE output up to page granularity
    full = (len(PROMPT) + len(a_out)) // PAGE
    assert on.prefix_index.match_len(b_prompt) >= full * PAGE
    assert s["relay_hit_tokens"] > 0.5 * len(a_out), s
    assert s["relay_hit_ratio"] > 0.0
    on.block_pool.check_invariants()
    on.prefix_index.check_invariants()
    assert on.block_pool.active_count == 0       # everything released

    off = _relay_engine(base, relay=False, **kw)
    a_ref, _, b_ref = _chain(off, PROMPT)
    so = off.stats()
    assert so["relay_publishes"] == 0 and so["relay_hit_tokens"] == 0
    assert (a_out, b_on) == (a_ref, b_ref), \
        "relay reuse must never change tokens"


@pytest.mark.parametrize("chunked", [False, True], ids=["eager", "chunked"])
def test_relay_pages_bit_identical_to_cold_prefill(base, chunked):
    """The decode-written pages the tree serves are BIT-IDENTICAL to what a
    cold prefill of the same stream would have written — the invariant that
    makes zero-copy relay sound (no recompute-and-compare at lookup)."""
    hot = _relay_engine(base, chunked=chunked)
    a_out = list(hot.generate("a", PROMPT,
                              SamplingParams(max_tokens=12)).result())
    stream = PROMPT + [2] + [int(t) for t in a_out[:-1]]
    hot_blocks, n = hot.prefix_index.match(stream)
    assert n == (len(stream) // PAGE) * PAGE and hot_blocks
    assert any(hot.prefix_index._by_block[b].provenance == "relay"
               for b in hot_blocks)

    cold = _relay_engine(base, chunked=chunked)
    cold.generate("a", stream, SamplingParams(max_tokens=1)).result()
    cold_blocks, m = cold.prefix_index.match(stream)
    assert m == n
    for hb, cb in zip(hot_blocks, cold_blocks):
        for g in hot.kvpool.k_groups:
            assert np.array_equal(
                np.asarray(hot.kvpool.k_groups[g][:, hb]),
                np.asarray(cold.kvpool.k_groups[g][:, cb]))
            assert np.array_equal(
                np.asarray(hot.kvpool.v_groups[g][:, hb]),
                np.asarray(cold.kvpool.v_groups[g][:, cb]))


# ======================================================================
# publication gate: only KV-path-identical decoders publish


def test_incompatible_decoder_skips_publish(base):
    """A decoder with different weights writes different KV: finish must
    NOT publish, and the skip is counted."""
    other = init_params(CFG, jax.random.PRNGKey(7))
    eng = LocalDisaggEngine(CFG, base, {"m0": other}, num_pages=64,
                            page_size=PAGE, chunked=True)
    eng.generate("m0", PROMPT, SamplingParams(max_tokens=12)).result()
    s = eng.stats()
    assert s["relay_publishes"] == 0 and s["relay_pages_published"] == 0
    assert s["relay_skipped"] >= 1
    assert s["relay_nodes"] == 0 and eng.prefix_index.relay_nodes == 0


def test_kv_neutral_tune_publishes_kv_feeding_tune_does_not():
    """The compatibility check is per-leaf: tuning layers AFTER the KV is
    written (unembed / final_norm) keeps the decoder relay-compatible;
    tuning the input embedding (which feeds every KV write) does not."""
    cfg = ModelConfig(name="relay-untied", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=64, dtype="float32", tie_embeddings=False)
    b2 = init_params(cfg, jax.random.PRNGKey(0))
    bump = lambda t: jax.tree_util.tree_map(lambda x: x + 0.25, t)  # noqa: E731
    head = dict(b2, unembed=bump(b2["unembed"]),
                final_norm=bump(b2["final_norm"]))
    emb = dict(b2, embed=bump(b2["embed"]))
    eng = LocalDisaggEngine(cfg, b2, num_pages=64, page_size=PAGE,
                            chunked=True)
    eng.models.register("head", head)
    eng.models.register("emb", emb)
    eng.generate("head", PROMPT, SamplingParams(max_tokens=PAGE + 2)).result()
    assert eng.stats()["relay_publishes"] == 1
    eng.generate("emb", _tok(9, 20), SamplingParams(max_tokens=PAGE + 2)) \
       .result()
    s = eng.stats()
    assert s["relay_publishes"] == 1 and s["relay_skipped"] >= 1


def test_lora_decoder_never_publishes(base):
    """LoRA perturbs attention weights inside the decode step, so its KV is
    not the base module's KV: never published."""
    tree = lora_init(jax.random.PRNGKey(5), base, rank=4)
    flat, td = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: x is None or isinstance(x, LoRAPair))
    flat = [None if p is None else
            LoRAPair(p.A, 0.05 * jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(5), 77 + i),
                p.B.shape, p.B.dtype))
            for i, p in enumerate(flat)]
    adapter = LoRAAdapter(jax.tree_util.tree_unflatten(td, flat),
                          alpha=8.0, rank=4)
    eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE,
                            chunked=True)
    eng.models.register("lora", adapter)
    eng.generate("lora", PROMPT, SamplingParams(max_tokens=PAGE + 2)).result()
    s = eng.stats()
    assert s["relay_publishes"] == 0 and s["relay_skipped"] >= 1


def test_prefix_cache_off_degrades_relay_off(base):
    """relay requires the global tree: with prefix_cache=False the Null
    index adopts nothing and the engine resolves relay to off."""
    eng = _relay_engine(base, prefix_cache=False)
    assert eng.relay is False
    _chain(eng, PROMPT)
    s = eng.stats()
    assert s["relay_publishes"] == 0 and s["relay_nodes"] == 0


# ======================================================================
# satellite: abort x relay — pages to baseline, tree intact, sanitize clean


def test_abort_paths_free_pages_to_baseline(base):
    """Abort the CONSUMER mid-prefill (holding relay pages as cached
    prefix), then abort a PRODUCER mid-decode (before it could publish):
    free-page counts return exactly to baseline, the tree keeps its relay
    nodes, the sanitizer's census stays clean, and the chain still
    completes bit-identically afterwards."""
    eng = _relay_engine(base, chunked=True, chunk_size=PAGE, sanitize=True)
    baseline = eng.block_pool.free_count
    a_out = list(eng.generate("a", PROMPT,
                              SamplingParams(max_tokens=12)).result())
    assert eng.stats()["relay_pages_published"] > 0
    assert eng.block_pool.free_count == baseline   # published pages: CACHED
    relay_bids = {b for b, nd in eng.prefix_index._by_block.items()
                  if nd.provenance == "relay"}
    b_prompt = PROMPT + [2] + [int(t) for t in a_out]

    hb = eng.generate("b", b_prompt, SamplingParams(max_tokens=6))
    eng.scheduler.step()                           # mid-prefill, prefix held
    assert eng.abort(hb) is True
    eng.scheduler.step()                           # sanitized census passes
    assert eng.block_pool.free_count == baseline
    assert relay_bids <= set(eng.prefix_index._by_block), \
        "abort must not tear published pages out of the tree"

    pubs = eng.stats()["relay_publishes"]
    ha = eng.generate("a", _tok(4, 20), SamplingParams(max_tokens=12))
    for _ in range(32):
        eng.scheduler.step()
        if eng.scheduler.active:
            break
    assert eng.abort(ha) is True
    eng.scheduler.step()
    assert eng.stats()["relay_publishes"] == pubs, \
        "aborted sequences never publish"
    assert eng.block_pool.free_count == baseline
    eng.block_pool.check_invariants()
    eng.prefix_index.check_invariants()

    b_on = list(eng.generate("b", b_prompt,
                             SamplingParams(max_tokens=6)).result())
    off = _relay_engine(base, relay=False, chunked=True, chunk_size=PAGE)
    off.generate("a", PROMPT, SamplingParams(max_tokens=12)).result()
    b_ref = list(off.generate("b", b_prompt,
                              SamplingParams(max_tokens=6)).result())
    assert b_on == b_ref


def test_relay_node_eviction_under_pressure(base):
    """A pool small enough to force LRU eviction of relay nodes: no lookup
    ever returns an evicted page, invariants hold, and re-running the
    consumer prompt (now a cold re-prefill) is still bit-identical."""
    eng = _relay_engine(base, num_pages=12, chunked=True, chunk_size=PAGE)
    a_out, b_prompt, b_first = _chain(eng, PROMPT)
    for i in range(6):                             # churn: evict relay nodes
        eng.generate("a", _tok(60 + i, 3 * PAGE),
                     SamplingParams(max_tokens=2)).result()
    assert eng.block_pool.stats.evictions > 0
    eng.prefix_index.check_invariants()
    for bid in eng.prefix_index._by_block:         # tree never points at FREE
        assert (eng.block_pool.refcount(bid) > 0
                or bid in eng.block_pool._cached)
    b_again = list(eng.generate("b", b_prompt,
                                SamplingParams(max_tokens=6)).result())
    assert b_again == b_first
    eng.block_pool.check_invariants()


# ======================================================================
# satellites: router pricing + stats surface


def test_router_prices_relayed_tokens_as_cached(base):
    """prefix_aware routing consults match_len, which walks the one global
    tree: relayed pages price exactly like prefill-cached ones, so the
    router sends the consumer where only the tail is cold."""
    eng = _relay_engine(base, chunked=True, n_prefill_workers=2)
    a_out, b_prompt, _ = _chain(eng, PROMPT)
    full = (len(PROMPT) + len(a_out)) // PAGE
    for w in eng.prefill_workers:
        assert w.mgr.index.match_len(b_prompt) >= full * PAGE


def test_stats_surface_relay_fields(base):
    """engine.stats() exposes the relay counters, the relay share of the
    cached-page gauge, and keeps pages_cached covering BOTH provenances."""
    eng = _relay_engine(base, chunked=True)
    _chain(eng, PROMPT)
    s = eng.stats()
    for k in ("relay_publishes", "relay_pages_published", "relay_skipped",
              "relay_hit_tokens", "relay_hit_ratio", "pages_cached_relay",
              "relay_nodes"):
        assert k in s, k
    assert s["relay_nodes"] >= 1 and s["pages_cached_relay"] >= 1
    assert s["pages_cached"] >= s["pages_cached_relay"]
