"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate a REDUCED same-family
variant (2+ layers, d_model<=512, <=4 experts) and run one forward pass and
one train step on CPU, asserting output shapes and no NaNs. Full configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import encode, forward, init_cache, init_params, train_loss
from repro.training.optim import AdamW, apply_updates

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=12):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jax.random.normal(KEY, (B, 10, cfg.d_model)) * 0.1
    if cfg.input_mode == "mixed":
        kw["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_embeds, cfg.d_model)) * 0.1
    return toks, kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    params = init_params(cfg, KEY)
    toks, kw = _inputs(cfg)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, kw["enc_embeds"])
        assert enc_out.shape == kw["enc_embeds"].shape
    logits, _, _ = forward(cfg, params, toks, logits="all", enc_out=enc_out,
                           prefix_embeds=kw.get("prefix_embeds"))
    S_out = toks.shape[1] + (cfg.n_prefix_embeds if cfg.input_mode == "mixed"
                             else 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    toks, kw = _inputs(cfg)
    tgt = jnp.roll(toks, -1, 1)
    mask = jnp.ones_like(toks, jnp.float32)

    def lf(p):
        loss, _ = train_loss(cfg, p, toks, tgt, mask, remat=True,
                             prefix_embeds=kw.get("prefix_embeds"),
                             enc_embeds=kw.get("enc_embeds"))
        return loss

    loss0, grads = jax.value_and_grad(lf)(params)
    assert jnp.isfinite(loss0)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0
    opt = AdamW(1e-3)
    upd, _ = opt.update(grads, opt.init(params), params)
    params2 = apply_updates(params, upd)
    loss1 = lf(params2)
    assert jnp.isfinite(loss1)
    assert float(loss1) < float(loss0) + 0.5  # one step doesn't explode


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    toks, kw = _inputs(cfg)
    enc_out = encode(cfg, params, kw["enc_embeds"]) if cfg.is_encdec else None
    npfx = cfg.n_prefix_embeds if cfg.input_mode == "mixed" else 0
    B, S = toks.shape
    cache = init_cache(cfg, B, S + npfx + 4,
                       enc_len=10 if cfg.is_encdec else 0)
    out, cache, _ = forward(cfg, params, toks, cache=cache,
                            pos=jnp.zeros(B, jnp.int32), enc_out=enc_out,
                            prefix_embeds=kw.get("prefix_embeds"))
    nt = jnp.argmax(out, -1)[:, None]
    out2, _, _ = forward(cfg, params, nt, cache=cache,
                         pos=jnp.full((B,), S + npfx, jnp.int32))
    full, _, _ = forward(cfg, params, jnp.concatenate([toks, nt], 1),
                         logits="all", enc_out=enc_out,
                         prefix_embeds=kw.get("prefix_embeds"))
    assert float(jnp.abs(out2 - full[:, -1]).max()) < 2e-3
