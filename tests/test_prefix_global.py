"""Engine-global radix prefix cache: automatic cross-worker KV reuse with no
SharedContext, multi-callback eviction fan-out, the ``prefix_cache=False``
A/B escape hatch, and control-plane invariants under random interleavings."""
import random

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kvcache.blocks import BlockPool, PoolExhausted
from repro.kvcache.radix import NullPrefixIndex, PrefixIndex
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="prefix-eng", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab_size=64, dtype="float32")
PAGE = 8


@pytest.fixture(scope="module")
def params():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(2)}
    return base, decs


def _engine(params, **kw):
    base, decs = params
    kw.setdefault("num_pages", 96)
    kw.setdefault("page_size", PAGE)
    return LocalDisaggEngine(CFG, base, decs, **kw)


def _tok(seed, n):
    return list(np.random.default_rng(seed).integers(4, 60, size=n))


def _fleet(eng, prefix, n=6, max_tokens=3):
    """n sequential plain generates (two models, NO SharedContext) sharing
    ``prefix``; returns the token streams. Sequential so the first request
    has published the prefix before the rest look it up (chunked mode
    commits at promote)."""
    streams = []
    for i in range(n):
        out = eng.generate(f"m{i % 2}", prefix + _tok(100 + i, 5 + i),
                           SamplingParams(max_tokens=max_tokens))
        streams.append(list(out.result()))
    return streams


# ======================================================================
# tentpole headline: automatic cross-worker reuse, bit-identical to cache-off


def test_automatic_cross_worker_reuse_bit_identical(params):
    """Repeated-prefix workload over TWO prefill workers and two decode
    models, no SharedContext anywhere: the engine-global tree serves >0.5x
    the shareable prefix tokens, both workers get hits, and every token
    stream is bit-identical to a prefix_cache=False run."""
    kw = dict(chunked=True, chunk_size=2 * PAGE, token_budget=4 * PAGE,
              n_prefill_workers=2)
    prefix = _tok(0, 4 * PAGE)
    n = 6

    on = _engine(params, **kw)
    got = _fleet(on, prefix, n=n)
    s = on.stats()
    shareable = (n - 1) * len(prefix)
    assert s["prefix_hit_tokens"] > 0.5 * shareable, s
    assert s["prefix_hit_ratio"] > 0.0
    # ephemeral sids alternate pinned homes, so BOTH workers served traffic
    # and hit the ONE shared tree (a per-worker tree would miss every other
    # request here)
    assert all(w.mgr.stats.lookups > 0 for w in on.prefill_workers)
    assert sum(w.mgr.stats.hit_tokens > 0 for w in on.prefill_workers) == 2
    on.block_pool.check_invariants()
    on.prefix_index.check_invariants()
    assert on.block_pool.active_count == 0    # ephemeral sessions all ended

    off = _engine(params, **kw, prefix_cache=False)
    ref = _fleet(off, prefix, n=n)
    assert off.stats()["prefix_hit_tokens"] == 0
    assert got == ref, "prefix reuse must never change tokens"
    # and the cache genuinely skipped work: fewer pages ever allocated
    assert on.block_pool.stats.allocs < off.block_pool.stats.allocs


def test_eager_engine_automatic_reuse(params):
    """The eager (non-chunked) path reuses through the same global tree."""
    prefix = _tok(1, 3 * PAGE)
    on = _engine(params, n_prefill_workers=2)
    got = _fleet(on, prefix, n=4)
    assert on.stats()["prefix_hit_tokens"] >= 3 * 3 * PAGE
    ref = _fleet(_engine(params, n_prefill_workers=2, prefix_cache=False),
                 prefix, n=4)
    assert got == ref


def test_plain_requests_hit_shared_context_prefix(params):
    """SharedContext interaction: pages a SharedContext published are visible
    to UNRELATED plain requests through the same global tree (the context
    adds a residency guarantee on top, not a separate namespace)."""
    eng = _engine(params, chunked=True, chunk_size=2 * PAGE,
                  token_budget=4 * PAGE)
    prefix = _tok(2, 3 * PAGE)
    with eng.shared_context(prefix) as ctx:
        assert len(ctx.tokens) == len(prefix)
        out = eng.generate("m1", prefix + _tok(3, 6),
                           SamplingParams(max_tokens=2))
        out.result()
    assert eng.stats()["prefix_hit_tokens"] >= 3 * PAGE
    eng.block_pool.check_invariants()


# ======================================================================
# satellite: multi-callback eviction fan-out (control plane, no engine)


def test_multi_callback_eviction_notifies_every_index():
    """A pool with SEVERAL registered indexes must notify each of them when
    a page is reclaimed — none may serve a stale match afterwards — and
    refcounts return exactly to baseline."""
    pool = BlockPool(8, 4)
    ia, ib = PrefixIndex(4), PrefixIndex(4)
    pool.add_evict_callback(ia.remove_block)
    pool.add_evict_callback(ib.remove_block)

    toks = list(range(16))                      # 4 full blocks
    blocks = pool.alloc(4)
    ia.insert(toks, blocks)
    ib.insert(toks, blocks)
    other = pool.alloc(4)                       # drain the free list
    pool.unref(blocks)                          # ACTIVE -> CACHED, LRU head
    pool.unref(other)
    base = pool.free_count
    assert base == 8

    # evict ONE page: the LRU victim is the chain head, so remove_block's
    # subtree semantics must clear the whole chain from BOTH indexes
    head = pool.alloc(1)
    assert pool.stats.evictions == 1
    for idx in (ia, ib):
        got, n = idx.match(toks)
        assert (got, n) == ([], 0), "stale match after eviction"
        assert len(idx) == 0
        idx.check_invariants()

    # churn every remaining cached page through a full eviction cycle
    rest = pool.alloc(7)
    assert pool.stats.evictions == 8
    pool.unref(head)
    pool.unref(rest)
    assert pool.free_count == base              # refcounts to baseline
    pool.check_invariants()


def test_null_index_registers_nothing():
    """NullPrefixIndex is inert end to end: misses, publishes nothing,
    survives eviction callbacks."""
    pool = BlockPool(4, 4)
    null = NullPrefixIndex(4)
    pool.add_evict_callback(null.remove_block)
    blocks = pool.alloc(2)
    assert null.insert(list(range(8)), blocks) == 0
    assert null.match(list(range(8))) == ([], 0)
    assert null.match_len(list(range(8))) == 0
    assert len(null) == 0 and null.lru_leaves(4) == []
    pool.unref(blocks)
    pool.alloc(4)                               # evictions fire into null
    null.check_invariants()
    pool.check_invariants()


def test_engine_eviction_no_stale_match(params):
    """Under a pool small enough to force evictions, the global tree never
    references a freed page and re-running an evicted prompt is still
    bit-identical (it just re-prefills)."""
    kw = dict(num_pages=14, chunked=True, chunk_size=2 * PAGE,
              token_budget=4 * PAGE)
    eng = _engine(params, **kw)
    prompts = [_tok(50 + i, 3 * PAGE) for i in range(6)]
    first = [list(eng.generate("m0", p, SamplingParams(max_tokens=2)).result())
             for p in prompts]
    assert eng.block_pool.stats.evictions > 0
    eng.prefix_index.check_invariants()
    # every page the tree still references is CACHED or ACTIVE, never free
    for bid in eng.prefix_index._by_block:
        assert (eng.block_pool.refcount(bid) > 0
                or bid in eng.block_pool._cached)
    again = [list(eng.generate("m0", p, SamplingParams(max_tokens=2)).result())
             for p in prompts]
    assert again == first
    ref = _engine(params, **kw, prefix_cache=False)
    assert [list(ref.generate("m0", p, SamplingParams(max_tokens=2)).result())
            for p in prompts] == first
    eng.block_pool.check_invariants()


# ======================================================================
# satellite: seeded-random interleaving invariants (always runs; the
# hypothesis variant in test_radix_properties.py goes deeper when available)


def test_random_interleaving_pool_index_invariants():
    """500 random insert/match+ref/release/lru_leaves steps against a shared
    pool+index: invariants hold throughout and match never returns a page
    that an eviction callback removed."""
    rng = random.Random(0)
    pool = BlockPool(32, 4)
    idx = PrefixIndex(4)
    evicted: set[int] = set()

    def on_evict(bid):
        evicted.add(bid)
        idx.remove_block(bid)

    pool.add_evict_callback(on_evict)
    live: list[list[int]] = []
    for step in range(500):
        op = rng.random()
        if op < 0.45:
            toks = [rng.randrange(3) for _ in range(rng.randint(1, 24))]
            got, n = idx.match(toks)
            assert n == 4 * len(got) <= len(toks)
            assert not (set(got) & evicted), "matched an evicted page"
            pool.ref(got)                       # a hit refs before alloc
            need = -(-len(toks) // 4) - len(got)
            try:
                new = pool.alloc(need)
            except PoolExhausted:
                pool.unref(got)
                continue
            evicted -= set(new)                 # recycled ids are live again
            idx.insert(toks, got + new)
            live.append(got + new)
        elif op < 0.85 and live:
            pool.unref(live.pop(rng.randrange(len(live))))
        else:
            for bid in idx.lru_leaves(rng.randint(0, 4)):
                assert bid not in evicted
        idx.check_invariants()
        pool.check_invariants()
    assert pool.stats.evictions > 0, "workload never exercised eviction"
