"""Model-lifecycle registry (serving/registry.py): hot (un)register decode
models while the engine serves — duplicate/unknown-id errors, drain vs abort
retirement, page refcounts back to baseline after churn, bit-identical
surviving streams across fused-plane lane remaps, and LoRA-spec'd models
(one base copy + stacked adapters, merged inside the jitted vmapped step)
asserted bit-identical to pre-merged ``lora_apply`` decoders."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.lora import LoRAPair, lora_apply, lora_init
from repro.models import init_params
from repro.serving.api import SamplingParams, UnknownModelError
from repro.serving.engine import LocalDisaggEngine
from repro.serving.registry import (DecodeModelSpec, LoRAAdapter,
                                    ModelRegistry, as_spec)

CFG = ModelConfig(name="reg-eng", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                  dtype="float32")
PAGE = 8


@pytest.fixture(scope="module")
def params():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(3)}
    return base, decs


def _engine(params, models=("m0", "m1", "m2"), **kw):
    base, decs = params
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    eng = LocalDisaggEngine(CFG, base, **kw)
    for mid in models:
        eng.models.register(mid, DecodeModelSpec(full=decs[mid]))
    return eng


def _ctx(seed, n=19):
    return list(np.random.default_rng(seed).integers(4, 60, size=n))


def _adapter(key, base, rank=4, alpha=8.0) -> LoRAAdapter:
    """lora_init with nonzero B so the merge is a real perturbation."""
    tree = lora_init(key, base, rank=rank)
    flat, td = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: x is None or isinstance(x, LoRAPair))
    out = [None if p is None else
           LoRAPair(p.A, 0.05 * jax.random.normal(
               jax.random.fold_in(key, 77 + i), p.B.shape, p.B.dtype))
           for i, p in enumerate(flat)]
    return LoRAAdapter(jax.tree_util.tree_unflatten(td, out),
                       alpha=alpha, rank=rank)


# ======================================================================
# registration errors / spec validation


def test_register_duplicate_raises(params):
    eng = _engine(params, models=("m0",))
    with pytest.raises(ValueError, match="already registered"):
        eng.models.register("m0", DecodeModelSpec(full=params[1]["m1"]))
    assert eng.models.list() == ["m0"]          # registry unchanged


def test_unregister_unknown_raises(params):
    eng = _engine(params, models=("m0",))
    with pytest.raises(UnknownModelError, match="'ghost' is not registered"):
        eng.models.unregister("ghost")
    with pytest.raises(UnknownModelError, match="not registered"):
        eng.models.get("ghost")


def test_generate_unknown_model_is_first_class(params):
    """Unknown-model submissions fail with UnknownModelError BEFORE any rid
    or pages exist — on generate, on SharedContext.generate, and on the
    legacy submit shim."""
    eng = _engine(params, models=("m0",))
    free0 = eng.block_pool.free_count
    with pytest.raises(UnknownModelError, match="'nope' is not registered"):
        eng.generate("nope", _ctx(0))
    with pytest.raises(UnknownModelError):
        with eng.shared_context(_ctx(1)) as ctx:
            ctx.generate("nope")
    with pytest.raises(UnknownModelError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng.submit(9, _ctx(0), "nope", 4)
    eng.run()
    assert eng.block_pool.free_count == free0
    # the failed submissions issued no rids: the next request works normally
    out = eng.generate("m0", _ctx(0), SamplingParams(max_tokens=3))
    assert out.result().shape == (3,)


def test_spec_validation():
    with pytest.raises(ValueError, match="exactly one"):
        DecodeModelSpec()
    with pytest.raises(ValueError, match="exactly one"):
        DecodeModelSpec(full={"w": 1}, lora=LoRAAdapter(params=None))
    with pytest.raises(TypeError, match="LoRAAdapter"):
        DecodeModelSpec(lora={"A": 1, "B": 2})
    assert as_spec({"w": 1}).kind == "full"
    assert as_spec(LoRAAdapter(params=None)).kind == "lora"


def test_constructor_dict_is_deprecated_shim(params):
    """The construction-time decoders dict still works — it registers each
    entry (token-identical to explicit registration) and warns."""
    base, decs = params
    with pytest.warns(DeprecationWarning, match="decoders"):
        old = LocalDisaggEngine(CFG, base, dict(decs), num_pages=64,
                                page_size=PAGE)
    assert old.models.list() == sorted(decs)
    assert old.stats.model_churn_events == 0     # construction is not churn
    new = _engine(params)
    ctx = _ctx(5)
    np.testing.assert_array_equal(
        old.generate("m1", ctx, SamplingParams(max_tokens=5)).result(),
        new.generate("m1", ctx, SamplingParams(max_tokens=5)).result())


# ======================================================================
# churn while serving


def test_hot_register_mid_run_preserves_surviving_outputs(params):
    """Registering a model while requests are decoding relayouts the fused
    plane at a step boundary; surviving requests' greedy outputs are
    bit-identical to a churn-free run, and the new model serves."""
    base, decs = params
    ref = _engine(params, models=("m0", "m1"))
    jobs = [( _ctx(10), "m0", 8), (_ctx(11, 13), "m1", 8)]
    refs = [ref.generate(m, c, SamplingParams(max_tokens=g))
            for c, m, g in jobs]
    ref.run()

    eng = _engine(params, models=("m0", "m1"))
    outs = [eng.generate(m, c, SamplingParams(max_tokens=g))
            for c, m, g in jobs]
    for _ in range(3):
        eng.step()                                # mid-generation...
    assert all(len(o.tokens) == 3 for o in outs)
    eng.models.register("m2", DecodeModelSpec(full=decs["m2"]))  # ...churn
    late = eng.generate("m2", _ctx(12, 17), SamplingParams(max_tokens=4))
    eng.run()
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o.tokens, r.tokens)
    m2_ref = _engine(params).generate("m2", _ctx(12, 17),
                                      SamplingParams(max_tokens=4))
    np.testing.assert_array_equal(late.result(), m2_ref.result())
    assert eng.stats.plane_rebuilds >= 1


def test_unregister_drain_finishes_inflight_then_retires(params):
    eng = _engine(params, models=("m0", "m1"))
    ref = _engine(params, models=("m0", "m1"))
    out = eng.generate("m0", _ctx(20), SamplingParams(max_tokens=8))
    eng.step()
    done_now = eng.models.unregister("m0", drain=True)
    assert done_now is False and "m0" in eng.models
    assert "m0" in eng.models.draining
    with pytest.raises(UnknownModelError, match="draining"):
        eng.generate("m0", _ctx(21))             # no NEW work while draining
    with pytest.raises(ValueError, match="already draining"):
        eng.models.unregister("m0")
    eng.run()                                    # in-flight request finishes
    assert out.finished and out.finish_reason == "length"
    np.testing.assert_array_equal(
        out.tokens,
        ref.generate("m0", _ctx(20), SamplingParams(max_tokens=8)).result())
    assert "m0" not in eng.models and eng.models.list() == ["m1"]
    with pytest.raises(UnknownModelError):
        eng.generate("m0", _ctx(21))


def test_unregister_abort_releases_pages_to_baseline(params):
    """drain=False aborts the model's in-flight work through the engine's
    abort path: aborted handles finish with reason 'abort', survivors are
    bit-identical, and the pool's free-page count returns to baseline."""
    eng = _engine(params, models=("m0", "m1"))
    free0 = eng.block_pool.free_count
    victim = eng.generate("m0", _ctx(30), SamplingParams(max_tokens=10))
    keeper = eng.generate("m1", _ctx(31, 13), SamplingParams(max_tokens=6))
    for _ in range(2):
        eng.step()
    assert eng.models.unregister("m0", drain=False) is True
    assert victim.finished and victim.finish_reason == "abort"
    assert len(victim.tokens) == 2               # streamed prefix survives
    assert "m0" not in eng.models
    eng.run()
    np.testing.assert_array_equal(
        keeper.tokens,
        _engine(params).generate("m1", _ctx(31, 13),
                                 SamplingParams(max_tokens=6)).result())
    assert eng.block_pool.free_count == free0
    eng.block_pool.check_invariants()


def test_churn_storm_page_accounting_and_plane_counters(params):
    """Interleaved register/unregister under traffic: free pages return to
    baseline once everything finishes, and the rebuilt plane's trace/
    dispatch counters stay cumulative (monotonic across relayouts)."""
    base, decs = params
    eng = _engine(params, models=("m0",))
    free0 = eng.block_pool.free_count
    a = eng.generate("m0", _ctx(40), SamplingParams(max_tokens=6))
    eng.step()
    d0 = eng.decode_plane.dispatches
    eng.models.register("m1", DecodeModelSpec(full=decs["m1"]))
    b = eng.generate("m1", _ctx(41, 11), SamplingParams(max_tokens=5))
    eng.step()
    eng.models.register("m2", DecodeModelSpec(full=decs["m2"]))
    eng.models.unregister("m1", drain=False)     # aborts b
    eng.models.unregister("m2")                  # never had traffic: gone now
    assert b.finish_reason == "abort"
    eng.run()
    assert a.finished and a.finish_reason == "length"
    assert eng.models.list() == ["m0"]
    assert eng.block_pool.free_count == free0
    assert eng.decode_plane.dispatches >= d0 + 1     # counters carried over
    assert eng.stats.plane_rebuilds >= 2
    eng.block_pool.check_invariants()


def test_seeded_stream_unchanged_across_lane_remap(params):
    """A seeded SAMPLED stream (keys fold from (seed, position)) is
    reproducible across a mid-stream churn event that remaps its fused-plane
    lane index."""
    sp = SamplingParams(max_tokens=8, temperature=0.9, top_k=12, seed=13)
    solo = _engine(params, models=("m1",)).generate(
        "m1", _ctx(50), sp).result()

    base, decs = params
    eng = _engine(params, models=("m0", "m1"))
    got = eng.generate("m1", _ctx(50), sp)
    eng.generate("m0", _ctx(51, 12), SamplingParams(max_tokens=3))
    for _ in range(2):
        eng.step()
    # churn both ways: m1's lane index changes (m0 retires below it, m2
    # arrives), while its pages / positions / sampling keys do not
    eng.models.register("m2", DecodeModelSpec(full=decs["m2"]))
    eng.run()
    eng.models.unregister("m0")
    got2 = eng.generate("m1", _ctx(50), sp)      # fresh run, remapped lane
    eng.run()
    np.testing.assert_array_equal(solo, got.tokens)
    np.testing.assert_array_equal(solo, got2.result())


def test_chunked_mode_churn_drain_and_abort(params):
    """Churn under the chunked scheduler: drain lets a still-PREFILLING
    request finish bit-identically; drain=False aborts it mid-chunk with
    pages back to baseline."""
    kw = dict(chunked=True, chunk_size=5, token_budget=16)
    ref = _engine(params, models=("m0",), **kw)
    r = ref.generate("m0", _ctx(60, 33), SamplingParams(max_tokens=5)).result()

    eng = _engine(params, models=("m0", "m1"), **kw)
    out = eng.generate("m0", _ctx(60, 33), SamplingParams(max_tokens=5))
    eng.step()                                   # first chunk only
    assert eng.models.unregister("m0", drain=True) is False
    eng.run()
    np.testing.assert_array_equal(out.tokens, r)
    assert "m0" not in eng.models

    eng2 = _engine(params, models=("m0", "m1"), **kw)
    free0 = eng2.block_pool.free_count
    out2 = eng2.generate("m0", _ctx(61, 33), SamplingParams(max_tokens=5))
    eng2.step()                                  # mid-prefill
    eng2.models.unregister("m0", drain=False)
    assert out2.finished and out2.finish_reason == "abort"
    eng2.run()
    assert eng2.block_pool.free_count == free0
    eng2.block_pool.check_invariants()


# ======================================================================
# LoRA specs: adapter-factored fused plane


def test_lora_spec_bit_identical_to_materialized(params):
    """LoRA-registered models (stacked A/B factors, merged inside the jitted
    vmapped step) decode bit-identically — greedy AND seeded sampling — to
    the same adapters pre-merged into full ``lora_apply`` decoders, while
    the fused plane stores one base copy + N adapter sets."""
    base, _ = params
    ads = {f"a{i}": _adapter(jax.random.PRNGKey(100 + i), base)
           for i in range(2)}
    lora_eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE)
    full_eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE)
    for mid, ad in ads.items():
        lora_eng.models.register(mid, DecodeModelSpec(lora=ad))
        full_eng.models.register(mid, DecodeModelSpec(full=lora_apply(
            base, ad.params, alpha=ad.alpha, rank=ad.rank)))
    jobs = [(_ctx(70), "a0", SamplingParams(max_tokens=6)),
            (_ctx(71, 13), "a1", SamplingParams(max_tokens=6)),
            (_ctx(72, 11), "a0",
             SamplingParams(max_tokens=6, temperature=0.8, top_k=10, seed=3))]
    louts = [lora_eng.generate(m, c, sp) for c, m, sp in jobs]
    fouts = [full_eng.generate(m, c, sp) for c, m, sp in jobs]
    lora_eng.run()
    full_eng.run()
    for lo, fo in zip(louts, fouts):
        np.testing.assert_array_equal(lo.tokens, fo.tokens)
    # weight-side Eq. 9: the lora plane stores exactly the stacked adapter
    # factors beyond the shared base; the full plane stores N full models
    ad_bytes = sum(x.nbytes for x in jax.tree.leaves(ads["a0"].params))
    full_bytes = sum(x.nbytes for x in jax.tree.leaves(
        full_eng.models.get("a0").full))
    assert lora_eng.decode_plane.param_bytes() == 2 * ad_bytes
    assert full_eng.decode_plane.param_bytes() == 2 * full_bytes
    assert lora_eng.decode_plane.param_bytes() \
        < full_eng.decode_plane.param_bytes() / 4


def test_mixed_full_and_lora_groups(params):
    """Full specs and LoRA specs coexist: they stack into separate fusable
    groups (one dispatch each per step) and both decode correctly alongside
    each other, including across a churn of either kind."""
    base, decs = params
    ad = _adapter(jax.random.PRNGKey(200), base)
    eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE)
    eng.models.register("full0", DecodeModelSpec(full=decs["m0"]))
    eng.models.register("lora0", DecodeModelSpec(lora=ad))
    o1 = eng.generate("full0", _ctx(80), SamplingParams(max_tokens=5))
    o2 = eng.generate("lora0", _ctx(80), SamplingParams(max_tokens=5))
    eng.run()
    assert len(eng.decode_plane.groups) == 2

    ref_full = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE)
    ref_full.models.register("full0", decs["m0"])
    np.testing.assert_array_equal(
        o1.tokens, ref_full.generate("full0", _ctx(80),
                                     SamplingParams(max_tokens=5)).result())
    ref_lora = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE)
    ref_lora.models.register("lora0", DecodeModelSpec(full=lora_apply(
        base, ad.params, alpha=ad.alpha, rank=ad.rank)))
    np.testing.assert_array_equal(
        o2.tokens, ref_lora.generate("lora0", _ctx(80),
                                     SamplingParams(max_tokens=5)).result())


def test_lora_spec_per_model_loop_and_lazy_materialization(params):
    """fused=False exercises the DecodeWorker path: the LoRA spec
    materializes ``lora_apply`` params lazily there, and outputs match the
    fused in-step merge bit-for-bit. In fused mode the worker copy is never
    materialized — the plane reads the factors directly."""
    base, _ = params
    ad = _adapter(jax.random.PRNGKey(300), base)
    fused_eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE)
    loop_eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE,
                                 fused=False)
    for eng in (fused_eng, loop_eng):
        eng.models.register("lm", DecodeModelSpec(lora=ad))
    a = fused_eng.generate("lm", _ctx(90), SamplingParams(max_tokens=6))
    b = loop_eng.generate("lm", _ctx(90), SamplingParams(max_tokens=6))
    fused_eng.run()
    loop_eng.run()
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert fused_eng.decoders["lm"]._dec_params is None     # never paid
    assert loop_eng.decoders["lm"]._dec_params is not None  # lazily paid


def test_registry_repr_and_queries(params):
    eng = _engine(params, models=("m0", "m1"))
    assert isinstance(eng.models, ModelRegistry)
    assert len(eng.models) == 2 and list(eng.models) == ["m0", "m1"]
    assert "m0" in eng.models and "zzz" not in eng.models
    assert eng.models.get("m0").kind == "full"
    assert "m0" in repr(eng.models)
