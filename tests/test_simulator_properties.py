"""Hypothesis property tests on the serving simulator's conservation laws."""
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions

CFG = get_config("internlm2-1.8b")   # small cost model => fast sim


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["baseline", "prefillshare"]),
       st.sampled_from(["react", "reflexion"]),
       st.integers(4, 20), st.floats(0.5, 8.0),
       st.sampled_from([8, 32, 128]))
def test_conservation(mode, pattern, n_sessions, rate, max_conc):
    sessions = make_sessions(pattern, n_sessions=n_sessions,
                             arrival_rate=rate, seed=7)
    sim = Simulator(CFG, ServingConfig(mode=mode, max_concurrent=max_conc,
                                       chips_per_worker=2,
                                       hbm_per_worker=32e9), sessions)
    r = sim.run()
    # every session completes; every invocation is recorded once
    assert r["sessions_done"] == n_sessions
    n_inv = sum(len(s.invocations) for s in sessions)
    assert len(sim.records) == n_inv
    # time sanity: issued <= done, TTFT > 0
    for rec in sim.records:
        assert rec.done >= rec.issued
        assert rec.ttft >= 0
    # hit ratio in [0, 1]; decode workers drained
    assert 0.0 <= r["prefix_hit_ratio"] <= 1.0
    assert all(not dw.active for dw in sim.decode)
    # admission cap respected throughout (post-hoc: concurrency counter is 0)
    assert sim.admitted == 0 and not sim.admission_queue
    # cache manager invariants survive the whole run
    for w in sim.prefill:
        w.mgr.pool.check_invariants()
        w.mgr.index.check_invariants()


@settings(max_examples=8, deadline=None)
@given(st.integers(6, 16), st.floats(1.0, 6.0),
       st.floats(0.5, 4.0), st.floats(0.005, 0.1))
def test_model_churn_conserves_work_and_prices_stalls(n_sessions, rate,
                                                      churn_s, rebuild_s):
    """Model-lifecycle churn (registry hot (un)register, rebuild cost
    stalling the decode plane) never loses work: every session still
    completes, stall accounting matches the event count, and the churned
    run is no faster end-to-end than the identical churn-free run."""
    runs = {}
    for interval in (0.0, churn_s):
        sessions = make_sessions("react", n_sessions=n_sessions,
                                 arrival_rate=rate, seed=5)
        sim = Simulator(CFG, ServingConfig(
            mode="prefillshare", max_concurrent=64, chips_per_worker=2,
            hbm_per_worker=32e9, churn_interval_s=interval,
            churn_rebuild_s=rebuild_s), sessions)
        runs[interval] = (sim.run(), sim)
    quiet, churned = runs[0.0][0], runs[churn_s][0]
    csim = runs[churn_s][1]
    assert quiet["churn_events"] == 0 and quiet["churn_stall_s"] == 0.0
    assert churned["sessions_done"] == n_sessions
    assert churned["churn_events"] == csim.churn_events > 0
    # every priced stall is one rebuild window on one busy decode worker
    assert abs(churned["churn_stall_s"]
               - csim.churn_stall_s) < 1e-9
    assert churned["churn_stall_s"] <= (churned["churn_events"]
                                        * rebuild_s * len(csim.decode) + 1e-9)
    # churn only ever costs time (progress freezes, tokens are never lost)
    assert churned["p95_e2e_s"] >= quiet["p95_e2e_s"] - 1e-6
    assert all(not dw.active for dw in csim.decode)


@settings(max_examples=8, deadline=None)
@given(st.integers(4, 16), st.floats(1.0, 6.0))
def test_prefillshare_never_worse_hit_ratio(n_sessions, rate):
    res = {}
    for mode in ("baseline", "prefillshare"):
        sessions = make_sessions("react", n_sessions=n_sessions,
                                 arrival_rate=rate, seed=11)
        sim = Simulator(CFG, ServingConfig(mode=mode, max_concurrent=64,
                                           chips_per_worker=2,
                                           hbm_per_worker=32e9), sessions)
        res[mode] = sim.run()
    assert (res["prefillshare"]["prefix_hit_ratio"]
            >= res["baseline"]["prefix_hit_ratio"] - 1e-9)
