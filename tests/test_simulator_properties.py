"""Hypothesis property tests on the serving simulator's conservation laws."""
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests only")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import make_sessions

CFG = get_config("internlm2-1.8b")   # small cost model => fast sim


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["baseline", "prefillshare"]),
       st.sampled_from(["react", "reflexion"]),
       st.integers(4, 20), st.floats(0.5, 8.0),
       st.sampled_from([8, 32, 128]))
def test_conservation(mode, pattern, n_sessions, rate, max_conc):
    sessions = make_sessions(pattern, n_sessions=n_sessions,
                             arrival_rate=rate, seed=7)
    sim = Simulator(CFG, ServingConfig(mode=mode, max_concurrent=max_conc,
                                       chips_per_worker=2,
                                       hbm_per_worker=32e9), sessions)
    r = sim.run()
    # every session completes; every invocation is recorded once
    assert r["sessions_done"] == n_sessions
    n_inv = sum(len(s.invocations) for s in sessions)
    assert len(sim.records) == n_inv
    # time sanity: issued <= done, TTFT > 0
    for rec in sim.records:
        assert rec.done >= rec.issued
        assert rec.ttft >= 0
    # hit ratio in [0, 1]; decode workers drained
    assert 0.0 <= r["prefix_hit_ratio"] <= 1.0
    assert all(not dw.active for dw in sim.decode)
    # admission cap respected throughout (post-hoc: concurrency counter is 0)
    assert sim.admitted == 0 and not sim.admission_queue
    # cache manager invariants survive the whole run
    for w in sim.prefill:
        w.mgr.pool.check_invariants()
        w.mgr.index.check_invariants()


@settings(max_examples=8, deadline=None)
@given(st.integers(4, 16), st.floats(1.0, 6.0))
def test_prefillshare_never_worse_hit_ratio(n_sessions, rate):
    res = {}
    for mode in ("baseline", "prefillshare"):
        sessions = make_sessions("react", n_sessions=n_sessions,
                                 arrival_rate=rate, seed=11)
        sim = Simulator(CFG, ServingConfig(mode=mode, max_concurrent=64,
                                           chips_per_worker=2,
                                           hbm_per_worker=32e9), sessions)
        res[mode] = sim.run()
    assert (res["prefillshare"]["prefix_hit_ratio"]
            >= res["baseline"]["prefix_hit_ratio"] - 1e-9)
