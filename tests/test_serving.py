"""Serving simulator + cost model behaviour (paper §4.3 mechanisms)."""
import numpy as np

from repro.configs import get_config
from repro.serving.costmodel import CostModel
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import PATTERNS, make_sessions

CFG = get_config("llama31-8b")


def _run(mode, rate=2.0, n=40, **kw):
    kw.setdefault("hbm_per_worker", 32e9)
    scfg = ServingConfig(mode=mode, chips_per_worker=2, **kw)
    sessions = make_sessions("react", n_sessions=n, arrival_rate=rate, seed=3)
    return Simulator(CFG, scfg, sessions).run()


def test_all_sessions_complete():
    for mode in ("baseline", "prefillshare"):
        r = _run(mode)
        assert r["sessions_done"] == 40
        assert r["throughput_tok_s"] > 0
        assert np.isfinite(r["p95_e2e_s"])


def test_model_churn_scenario_completes_and_prices_stalls():
    """Model-lifecycle churn (ServingConfig.churn_interval_s): a decode
    model hot-(un)registers mid-workload and each event's registry-rebuild
    cost freezes the decode plane. Work is conserved (all sessions finish),
    stalls are accounted, and the churned run is never faster."""
    quiet = _run("prefillshare")
    churned = _run("prefillshare", churn_interval_s=1.0,
                   churn_rebuild_s=0.05)
    assert churned["sessions_done"] == quiet["sessions_done"] == 40
    assert quiet["churn_events"] == 0 and quiet["churn_stall_s"] == 0.0
    assert churned["churn_events"] > 0
    assert churned["churn_stall_s"] > 0
    assert churned["p95_e2e_s"] >= quiet["p95_e2e_s"] - 1e-9
    assert churned["throughput_tok_s"] <= quiet["throughput_tok_s"] + 1e-6


def test_prefillshare_beats_baseline_on_hit_ratio():
    rb = _run("baseline")
    rp = _run("prefillshare")
    assert rp["prefix_hit_ratio"] > rb["prefix_hit_ratio"] + 0.1


def test_prefillshare_reduces_prefill_load():
    rb = _run("baseline")
    rp = _run("prefillshare")
    assert rp["prefill_busy_frac"] < rb["prefill_busy_frac"]


def test_baseline_degrades_under_load():
    """Paper Fig. 3: the gap widens as arrival rate grows."""
    lo_b, lo_p = _run("baseline", rate=0.5), _run("prefillshare", rate=0.5)
    hi_b, hi_p = _run("baseline", rate=8.0), _run("prefillshare", rate=8.0)
    gap_lo = lo_b["p95_e2e_s"] / lo_p["p95_e2e_s"]
    gap_hi = hi_b["p95_e2e_s"] / hi_p["p95_e2e_s"]
    assert gap_hi > gap_lo


def test_ttft_insensitive_to_context_for_prefillshare():
    """Eq. 9 consequence: shared-prefix reuse keeps mean TTFT low."""
    rb = _run("baseline", rate=4.0)
    rp = _run("prefillshare", rate=4.0)
    assert rp["mean_ttft_s"] < rb["mean_ttft_s"]


def test_deterministic():
    r1, r2 = _run("prefillshare"), _run("prefillshare")
    assert r1 == r2


def test_session_token_streams_agree_across_models():
    s = make_sessions("react", n_sessions=2, arrival_rate=1.0)[0]
    assert s.fresh_tokens(16, salt=1) == s.fresh_tokens(16, salt=1)
    assert s.fresh_tokens(16, salt=1) != s.fresh_tokens(16, salt=2)


def test_patterns_defined():
    for p, prof in PATTERNS.items():
        assert prof["turns"] >= 1 and prof["gen"] > 0


# ----------------------------------------------------------------------
# cost model


def test_costmodel_prefill_scales_with_tokens():
    cm = CostModel(CFG, chips=2)
    a = cm.prefill(1024, 0).seconds
    b = cm.prefill(4096, 0).seconds
    assert b > a


def test_costmodel_decode_memory_bound():
    cm = CostModel(CFG, chips=2)
    c = cm.decode_step(batch=8, avg_kv_len=4096)
    assert c.memory_s > c.compute_s        # decode is memory-bound


def test_costmodel_prefill_compute_bound():
    cm = CostModel(CFG, chips=2)
    c = cm.prefill(32768, 0, batch=1)
    assert c.compute_s > c.memory_s        # long prefill is compute-bound


# ----------------------------------------------------------------------
# Appendix-B.2 alternatives (beyond-paper)


def test_b2_policies_all_complete():
    from repro.serving.backpressure import POLICIES
    for pol in POLICIES:
        r = _run("prefillshare", rate=6.0, n=30, max_concurrent=160,
                 b2_policy=pol)
        assert r["sessions_done"] == 30, pol


def test_backpressure_eliminates_staging():
    r_stage = _run("prefillshare", rate=6.0, n=40, max_concurrent=160,
                   hbm_per_worker=24e9, b2_policy="staging")
    r_bp = _run("prefillshare", rate=6.0, n=40, max_concurrent=160,
                hbm_per_worker=24e9, b2_policy="backpressure")
    assert r_bp["staged_frac"] == 0.0
    # backpressure should not lose throughput vs staging under pressure
    assert r_bp["throughput_tok_s"] >= r_stage["throughput_tok_s"] * 0.9


def test_admission_control_caps_concurrency():
    from repro.serving.backpressure import B2Policy
    pol = B2Policy("admission", CFG, hbm_bytes=24e9,
                   weight_bytes=CFG.param_count() * 2,
                   max_context_tokens=4000)
    assert pol.session_cap(1000) < 1000
    assert pol.session_cap(1) == 1


def test_reservation_accounting():
    from repro.serving.backpressure import B2Policy
    pol = B2Policy("reservation", CFG, hbm_bytes=20e9,
                   weight_bytes=CFG.param_count() * 2,
                   max_context_tokens=4000)
    granted = sum(pol.try_reserve(i) for i in range(100))
    assert 0 < granted < 100          # finite reservable capacity
    pol.release(0)
    assert pol.try_reserve(999)       # freed budget is reusable
