import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_compile_state():
    """Drop JAX's in-process executable caches between test modules.

    The suite is one process compiling hundreds of toy-shape programs
    across ~24 modules; on small (1-core CI) machines the accumulated
    XLA/LLVM compiler state can segfault a late compile outright
    (observed deterministically in backend_compile around the 200th
    test). Modules build their own engines from their own toy configs,
    so cross-module cache reuse — and therefore the recompile cost of
    clearing — is negligible."""
    yield
    import jax

    jax.clear_caches()
