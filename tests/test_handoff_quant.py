"""Beyond-paper: int8 cache handoff — wire bytes halve, decode quality holds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefillshare import base_prefill
from repro.kvcache.handoff import (dequantize_cache, quantize_cache,
                                   quantized_bytes)
from repro.models import forward, init_params

CFG = ModelConfig(name="hq", arch_type="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")


def test_roundtrip_and_bytes():
    base = init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 4, 60)
    _, cache = base_prefill(CFG, base, toks, cache_len=32)
    qc = quantize_cache(cache)
    dq = dequantize_cache(qc)
    # structure preserved, values close
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(dq)):
        assert a.shape == b.shape and a.dtype == b.dtype
        if jnp.issubdtype(a.dtype, jnp.floating) and a.ndim >= 3:
            scale = float(jnp.abs(a).max()) + 1e-9
            assert float(jnp.abs(a - b).max()) / scale < 0.02
    fp_bytes = sum(x.nbytes for x in jax.tree.leaves(cache)
                   if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 3)
    assert quantized_bytes(cache) < 0.45 * fp_bytes + 4096


def test_decode_quality_from_quantized_cache():
    base = init_params(CFG, jax.random.PRNGKey(0))
    dec = init_params(CFG, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 4, 60)
    _, cache = base_prefill(CFG, base, toks, cache_len=32)
    cache_q = dequantize_cache(quantize_cache(cache))
    pos = jnp.full((2,), 24, jnp.int32)
    nxt = jnp.full((2, 1), 2, jnp.int32)
    lo_fp, _, _ = forward(CFG, dec, nxt, cache=cache, pos=pos)
    lo_q, _, _ = forward(CFG, dec, nxt, cache=cache_q, pos=pos)
    # logits drift bounded; argmax unchanged
    assert float(jnp.abs(lo_fp - lo_q).max()) < 5e-2
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lo_fp, -1)),
                                  np.asarray(jnp.argmax(lo_q, -1)))
