"""End-to-end behaviour tests for the PrefillShare system.

The heavyweight claims (Fig-2 curve, engine bit-equivalence) have dedicated
test modules; this file asserts the cross-cutting system invariants that tie
the layers together.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_all_assigned_archs_registered_with_exact_dims():
    expect = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    }
    assert set(ASSIGNED) == set(expect)
    for name, (L, d, h, kv, ff, V) in expect.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, V), name
        assert c.source, f"{name} missing citation"


def test_moe_configs():
    g = get_config("granite-moe-3b-a800m")
    assert g.n_experts == 40 and g.top_k == 8
    k = get_config("grok-1-314b")
    assert k.n_experts == 8 and k.top_k == 2


def test_long_context_eligibility():
    ok = {a for a in ASSIGNED if get_config(a).long_context_ok}
    assert ok == {"mamba2-780m", "recurrentgemma-2b", "gemma2-27b"}


def test_input_shapes_assigned():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_sharding_policy_divisibility():
    """No rule may ever produce an uneven shard on the production mesh."""
    import itertools

    from repro.launch.sharding import param_pspec
    shapes = [(49155, 1536), (1536, 6448), (40, 1536, 512), (8, 6144, 32768),
              (4096, 4096), (14336, 4096), (2, 46, 128), (256000, 4608)]
    for shape in shapes:
        for name in ("x/wo", "x/wi", "embed"):
            spec = param_pspec(name, shape, 16, 16)
            for dim, ax in itertools.zip_longest(shape, spec, fillvalue=None):
                if ax in ("model", "data"):
                    assert dim is not None and dim % 16 == 0, (name, shape, spec)


def test_mesh_shapes_subprocess():
    """make_production_mesh builds 16x16 and 2x16x16 (512 fake devices)."""
    code = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=512'\n"
        "import sys; sys.path.insert(0, %r)\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "m1 = make_production_mesh(); m2 = make_production_mesh(multi_pod=True)\n"
        "assert m1.devices.shape == (16, 16) and m1.axis_names == ('data', 'model')\n"
        "assert m2.devices.shape == (2, 16, 16)\n"
        "assert m2.axis_names == ('pod', 'data', 'model')\n"
        "print('ok')\n" % SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=240)
    assert r.returncode == 0 and "ok" in r.stdout, r.stderr[-800:]


def test_tiny_sharded_execution_subprocess():
    """Actually EXECUTE a sharded serve_step on an 8-device host mesh."""
    code = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=8'\n"
        "import sys; sys.path.insert(0, %r)\n"
        "import jax, jax.numpy as jnp, dataclasses\n"
        "from repro.configs import get_config\n"
        "from repro.configs.base import INPUT_SHAPES, InputShape\n"
        "from repro.launch.steps import build\n"
        "cfg = get_config('internlm2-1.8b').reduced()\n"
        "cfg = dataclasses.replace(cfg, name='t', vocab_size=512)\n"
        "mesh = jax.make_mesh((2, 4), ('data', 'model'))\n"
        "INPUT_SHAPES['tiny_dec'] = InputShape('tiny_dec', 64, 4, 'decode')\n"
        "b = build(cfg, 'tiny_dec', mesh)\n"
        "with mesh:\n"
        "    f = jax.jit(b['fn'], in_shardings=b['in_shardings'])\n"
        "    args = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), b['args'])\n"
        "    logits, cache = f(*args)\n"
        "    assert logits.shape == (4, cfg.vocab_size)\n"
        "    assert not bool(jnp.isnan(logits).any())\n"
        "print('ok')\n" % SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0 and "ok" in r.stdout, (r.stderr[-1500:])


def test_dryrun_results_if_present():
    """If the dry-run sweep has been run, every non-skipped combo must have
    compiled (this is the deliverable-e gate)."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run not executed yet")
    bad = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if "error" in r:
                bad.append((r["arch"], r["shape"], r.get("mesh")))
    assert not bad, f"dry-run failures: {bad}"


def test_cache_pspec_properties():
    """Decode caches shard seq on model; long-context shards seq on both."""
    pytest.importorskip("hypothesis", reason="optional dep")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from repro.launch.sharding import cache_pspec

    @settings(max_examples=200, deadline=None)
    @given(st.sampled_from(["k", "v", "kpos"]),
           st.sampled_from([1, 2, 8, 32, 128, 256]),
           st.sampled_from([2048, 4096, 32768, 524288]),
           st.sampled_from([64, 256, 1024, 2048]),
           st.booleans(), st.booleans())
    def check(leaf, B, T, F, stacked, decode):
        shape = ((4,) if stacked else ()) + ((B, T) if leaf == "kpos"
                                             else (B, T, F))
        name = ("groups/pos0/" if stacked else "tail/0/") + leaf
        spec = cache_pspec(name, shape, 16, 16, stacked=stacked,
                           decode=decode)
        # every sharded dim divides evenly
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                continue
            ways = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                ways *= 16
            assert dim % ways == 0, (shape, spec)
        # stacked leading dim never sharded
        if stacked:
            assert len(spec) == 0 or spec[0] is None

    check()
