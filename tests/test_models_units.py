"""Unit tests for model components: SSD oracle, RG-LRU oracle, MoE, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_apply, rglru_init, init_rglru_cache
from repro.models.rope import apply_rope
from repro.models.ssd import init_ssd_cache, ssd_apply, ssd_init, ssd_scan

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# SSD: chunked scan vs naive per-step recurrence oracle


def _naive_ssd(x, dt, A, B_, C_, init_state):
    Bb, S, H, P = x.shape
    state = init_state
    ys = []
    for t in range(S):
        da = jnp.exp(dt[:, t, :] * A[None])                       # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B_[:, t])
        state = state * da[..., None, None] + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", C_[:, t], state))
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("S", [1, 7, 64, 130])
def test_ssd_scan_matches_naive(S):
    Bb, H, P, N = 2, 3, 4, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (Bb, S, N))
    C_ = jax.random.normal(ks[4], (Bb, S, N))
    s0 = jnp.zeros((Bb, H, P, N))
    y1, f1 = ssd_scan(x, dt, A, B_, C_, s0, chunk=16)
    y2, f2 = _naive_ssd(x, dt, A, B_, C_, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4,
                               rtol=1e-4)


def test_ssd_scan_initial_state_used():
    Bb, S, H, P, N = 1, 8, 2, 4, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bb, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, H)))
    A = -jnp.ones((H,)) * 0.1
    B_ = jax.random.normal(ks[3], (Bb, S, N))
    C_ = jax.random.normal(ks[4], (Bb, S, N))
    s0 = jnp.ones((Bb, H, P, N))
    y1, _ = ssd_scan(x, dt, A, B_, C_, jnp.zeros_like(s0), chunk=4)
    y2, _ = ssd_scan(x, dt, A, B_, C_, s0, chunk=4)
    assert float(jnp.abs(y1 - y2).max()) > 1e-4


def test_ssd_block_decode_equals_prefill():
    cfg = ModelConfig(name="m", arch_type="ssm", n_layers=1, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=16,
                      ssm_state=8, ssm_head_dim=16, layer_pattern=("ssd",),
                      dtype="float32")
    p = ssd_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 9, 32)) * 0.3
    full, cache_full = ssd_apply(p, x, cfg, cache=init_ssd_cache(cfg, 2, jnp.float32))
    c = init_ssd_cache(cfg, 2, jnp.float32)
    _, c = ssd_apply(p, x[:, :8], cfg, cache=c)
    last, c = ssd_apply(p, x[:, 8:9], cfg, cache=c)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(last[:, 0]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_full["ssm"]),
                               np.asarray(c["ssm"]), atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------
# RG-LRU


def _naive_rglru_recurrence(a, b, h0):
    hs = []
    h = h0
    for t in range(a.shape[1]):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    return jnp.stack(hs, 1)


def test_rglru_decode_equals_scan():
    cfg = ModelConfig(name="g", arch_type="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=16,
                      rglru_width=32, layer_pattern=("rglru",),
                      dtype="float32")
    p = rglru_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 9, 32)) * 0.3
    full, cf = rglru_apply(p, x, cfg, cache=init_rglru_cache(cfg, 2, jnp.float32))
    c = init_rglru_cache(cfg, 2, jnp.float32)
    _, c = rglru_apply(p, x[:, :8], cfg, cache=c)
    last, c = rglru_apply(p, x[:, 8:9], cfg, cache=c)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(last[:, 0]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cf["h"]), np.asarray(c["h"]),
                               atol=1e-4)


def test_rglru_state_decays():
    """|a| < 1 always: bounded recurrence."""
    cfg = ModelConfig(name="g", arch_type="hybrid", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=16,
                      rglru_width=16, layer_pattern=("rglru",),
                      dtype="float32")
    p = rglru_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 100, 16))
    out, cache = rglru_apply(p, x, cfg,
                             cache=init_rglru_cache(cfg, 1, jnp.float32))
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(cache["h"]).max()) < 100.0


# ----------------------------------------------------------------------
# MoE


def _moe_cfg(E=4, K=2, cap=8.0):
    return ModelConfig(name="moe", arch_type="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=16,
                       n_experts=E, top_k=K, capacity_factor=cap,
                       dtype="float32")


def test_moe_full_capacity_matches_dense_computation():
    """With no drops, output == sum_k gate_k * expert_k(x) computed densely."""
    cfg = _moe_cfg()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 6, 16)) * 0.5
    out, aux = moe_apply(p, x, cfg)
    assert aux["dropped_frac"] == 0.0

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    dense = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi"][e]))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"][e])
        eo = jnp.einsum("bsf,fd->bsd", g * u, p["wo"][e])
        w = ((gi == e) * gv).sum(-1)
        dense = dense + w[..., None] * eo
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), atol=1e-4,
                               rtol=1e-4)


def test_moe_capacity_drops_counted():
    cfg = _moe_cfg(cap=0.26)     # tight capacity forces drops
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, 16))
    out, aux = moe_apply(p, x, cfg)
    assert 0.0 < float(aux["dropped_frac"]) < 1.0
    assert np.isfinite(np.asarray(out)).all()


def test_moe_lb_loss_favors_balance():
    cfg = _moe_cfg()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, 16))
    _, aux = moe_apply(p, x, cfg)
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz-ish


# ----------------------------------------------------------------------
# RoPE


def test_rope_relative_shift_invariance():
    """Dot products depend only on relative positions."""
    D = 16
    q = jax.random.normal(KEY, (1, 1, 1, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, D))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), style="full")
        kr = apply_rope(k, jnp.array([[pk]]), style="full")
        return float(jnp.sum(qr * kr))
    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), abs=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), abs=1e-4)


def test_rope_partial_passthrough():
    D = 16
    x = jax.random.normal(KEY, (1, 2, 1, D))
    r = apply_rope(x, jnp.array([[3, 4]]), style="partial")
    # second half untouched
    np.testing.assert_allclose(np.asarray(r[..., D // 2:]),
                               np.asarray(x[..., D // 2:]), atol=1e-6)
    assert float(jnp.abs(r[..., : D // 2] - x[..., : D // 2]).max()) > 1e-4
