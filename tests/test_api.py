"""Request-centric serving API (repro.serving.api): SamplingParams executed
in the decode planes, streaming RequestOutputs with finish reasons, abort
page-accounting at every lifecycle stage, SharedContext sessions, the
deprecated legacy shim, and chunk block-table bucketing."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.prefillshare import CHUNK_TRACES
from repro.models import init_params
from repro.serving.api import (FINISH_ABORT, FINISH_EOS, FINISH_LENGTH,
                               FINISH_STOP, SamplingParams)
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="api-eng", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                  dtype="float32")
PAGE = 8


@pytest.fixture(scope="module")
def params():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(2)}
    return base, decs


def _engine(params, **kw):
    base, decs = params
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    return LocalDisaggEngine(CFG, base, decs, **kw)


def _legacy_invoke(eng, sid, ctx, mid, gen):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return eng.invoke(sid, ctx, mid, gen_tokens=gen)


def _ctx(seed=0, n=19):
    return list(np.random.default_rng(seed).integers(4, 60, size=n))


# ======================================================================
# SamplingParams semantics


def test_temperature_zero_bit_identical_to_legacy_greedy(params):
    """generate(temperature=0) reproduces the pre-redesign greedy path
    token-for-token — fused and per-model, eager and chunked."""
    ctx = _ctx(0)
    ref = _legacy_invoke(_engine(params), 0, ctx, "m0", 6)
    for kw in (dict(),                                     # fused, eager
               dict(fused=False),                          # per-model, eager
               dict(chunked=True, chunk_size=5, token_budget=16)):  # chunked
        eng = _engine(params, **kw)
        out = eng.generate("m0", ctx, SamplingParams(max_tokens=6))
        np.testing.assert_array_equal(out.result(), ref, err_msg=str(kw))
        assert out.finish_reason == FINISH_LENGTH


def test_seeded_sampling_reproducible_regardless_of_batch_packing(params):
    """A seeded sampled stream depends only on (request, seed): running the
    same request alone, alongside other traffic, and under the chunked
    scheduler yields the SAME tokens (keys fold from (seed, position))."""
    ctx = _ctx(1)
    sp = SamplingParams(max_tokens=6, temperature=0.8, top_k=12, seed=7)

    solo = _engine(params).generate("m0", ctx, sp).result()
    assert len(set(solo.tolist())) > 1 or True        # stream materialized

    busy = _engine(params)
    busy.generate("m1", _ctx(2, 13), SamplingParams(max_tokens=9,
                                                    temperature=1.3, seed=3))
    busy.generate("m0", _ctx(3, 27), SamplingParams(max_tokens=4))
    got = busy.generate("m0", ctx, sp)
    busy.run()
    np.testing.assert_array_equal(solo, got.tokens)

    chunked = _engine(params, chunked=True, chunk_size=5, token_budget=16)
    chunked.generate("m1", _ctx(2, 13), SamplingParams(max_tokens=9,
                                                       temperature=1.3, seed=3))
    got2 = chunked.generate("m0", ctx, sp)
    chunked.run()
    np.testing.assert_array_equal(solo, got2.tokens)


def test_default_seed_gives_independent_fanout_draws(params):
    """seed=None (the default) means engine-assigned per-request seeds: N
    sampled generations over the SAME prompt and model are N different
    draws, not N copies of one stream."""
    eng = _engine(params)
    ctx = _ctx(30)
    sp = SamplingParams(max_tokens=6, temperature=1.0)
    assert sp.seed is None
    outs = [eng.generate("m0", ctx, sp) for _ in range(3)]
    eng.run()
    streams = [tuple(o.tokens) for o in outs]
    assert len(set(streams)) > 1, streams
    seeds = [o.params.seed for o in outs]       # resolved, visible, distinct
    assert len(set(seeds)) == 3 and None not in seeds


def test_abort_from_stream_callback_does_not_corrupt_other_streams(params):
    """RequestOutput.abort() invoked from INSIDE a stream callback (the
    'first agent answered, cancel the rest' pattern) fires mid decode-step:
    the step must finish with its original token/sequence alignment, so the
    surviving streams are unaffected."""
    ctxs = [_ctx(31 + i) for i in range(3)]
    refs = [_engine(params).generate(
        "m0", c, SamplingParams(max_tokens=6)).result() for c in ctxs]

    eng = _engine(params)
    outs = {}

    def killer(ro, tok):
        if len(ro.tokens) == 2:
            outs["b"].abort()                   # re-enters the engine

    outs["a"] = eng.generate("m0", ctxs[0], SamplingParams(max_tokens=6),
                             stream_callback=killer)
    outs["b"] = eng.generate("m0", ctxs[1], SamplingParams(max_tokens=6))
    outs["c"] = eng.generate("m0", ctxs[2], SamplingParams(max_tokens=6))
    eng.run()
    np.testing.assert_array_equal(outs["a"].result(), refs[0])
    np.testing.assert_array_equal(outs["c"].result(), refs[2])
    assert outs["b"].finish_reason == FINISH_ABORT
    # the abort fired during A's token-2 bookkeeping, BEFORE B's token-2 was
    # delivered: B keeps the delivered prefix of its reference stream (the
    # in-flight token is dropped, not mis-delivered)
    n = len(outs["b"].tokens)
    assert 1 <= n < 6
    np.testing.assert_array_equal(outs["b"].tokens, refs[1][:n])
    eng.block_pool.check_invariants()


def test_top_k_one_is_greedy_even_at_high_temperature(params):
    ctx = _ctx(4)
    greedy = _engine(params).generate(
        "m0", ctx, SamplingParams(max_tokens=5)).result()
    forced = _engine(params).generate(
        "m0", ctx, SamplingParams(max_tokens=5, temperature=5.0,
                                  top_k=1, seed=11)).result()
    np.testing.assert_array_equal(greedy, forced)


def test_top_p_renormalizes_over_top_k_survivors():
    """Nucleus filtering operates on the distribution AFTER top-k, not the
    raw one: with probs (.4,.3,.2,.1), top_k=2 renormalizes to (4/7, 3/7),
    so top_p=0.55 keeps only the argmax (exclusive mass of the runner-up is
    4/7 > 0.55) — the unrenormalized cut (0.4 < 0.55) would keep both."""
    import jax.numpy as jnp
    from repro.serving.sampling import fold_keys, sample_logits
    lg = jnp.log(jnp.array([[0.4, 0.3, 0.2, 0.1]], jnp.float32))
    keys = fold_keys(jnp.arange(1, dtype=jnp.int32),
                     jnp.arange(1, dtype=jnp.int32))
    for seed_pos in range(20):
        keys = fold_keys(jnp.array([seed_pos], jnp.int32),
                         jnp.array([seed_pos], jnp.int32))
        tok = sample_logits(lg, jnp.array([1.0], jnp.float32),
                            jnp.array([2], jnp.int32),
                            jnp.array([0.55], jnp.float32), keys)
        assert int(tok[0]) == 0, seed_pos
    # sanity: without the top-k squeeze, top_p=0.55 keeps tokens {0, 1}
    seen = set()
    for seed_pos in range(40):
        keys = fold_keys(jnp.array([seed_pos], jnp.int32),
                         jnp.array([seed_pos], jnp.int32))
        tok = sample_logits(lg, jnp.array([1.0], jnp.float32),
                            jnp.array([0], jnp.int32),
                            jnp.array([0.55], jnp.float32), keys)
        seen.add(int(tok[0]))
    assert seen == {0, 1}


def test_abort_after_final_token_before_reap_is_not_an_abort(params):
    """A sequence that already produced its last token but has not been
    reaped yet (reaping happens at the next step's top) is COMPLETE: abort
    must refuse, and the result must still materialize."""
    eng = _engine(params)
    out = eng.generate("m0", _ctx(22), SamplingParams(max_tokens=3))
    for _ in range(3):
        eng.step()
    assert len(out.tokens) == 3 and not out.finished   # generated, unreaped
    assert eng.abort(out) is False
    np.testing.assert_array_equal(out.result(), out.tokens)
    assert out.finish_reason == FINISH_LENGTH
    eng.block_pool.check_invariants()


def test_dense_fallback_generate_streams_to_callback(params):
    """paged=False (the SSM/hybrid fallback path) honours stream_callback
    and the RequestOutput contract even though generation is synchronous."""
    eng = _engine(params, paged=False, capacity=64)
    seen = []
    out = eng.generate("m0", _ctx(23), SamplingParams(max_tokens=4),
                       stream_callback=lambda ro, t: seen.append(t))
    assert out.finished and out.finish_reason == FINISH_LENGTH
    assert seen == out.tokens and len(seen) == 4


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=-1)
    assert SamplingParams(stop_token_ids=[3, 5]).stop_token_ids == (3, 5)


# ======================================================================
# finish reasons + early termination


def test_stop_and_eos_finish_reasons_truncate_the_stream(params):
    """stop_token_ids / eos_token_id end generation mid-flight: the stream
    is cut at (and includes) the terminating token, the finish reason names
    the cause, and the retired sequence's pages return to the pool."""
    ctx = _ctx(5)
    full = _engine(params).generate(
        "m0", ctx, SamplingParams(max_tokens=6)).result()

    stop_tok = int(full[2])
    cut = full.tolist().index(stop_tok) + 1    # first occurrence, inclusive
    assert cut < len(full)

    eng = _engine(params)
    baseline = eng.block_pool.free_count
    stop = eng.generate("m0", ctx, SamplingParams(
        max_tokens=6, stop_token_ids=[stop_tok]))
    np.testing.assert_array_equal(stop.result(), full[:cut])
    assert stop.finish_reason == FINISH_STOP
    eng.block_pool.check_invariants()
    assert eng.block_pool.free_count == baseline   # ephemeral session ended

    eos = _engine(params).generate("m0", ctx, SamplingParams(
        max_tokens=6, eos_token_id=stop_tok))
    np.testing.assert_array_equal(eos.result(), full[:cut])
    assert eos.finish_reason == FINISH_EOS


def test_early_finish_frees_budget_mid_flight(params):
    """An EOS-terminated sequence stops consuming decode steps: the engine
    advances only the surviving sequence afterwards (budget freed), and both
    requests' outputs are unaffected."""
    ctx_a, ctx_b = _ctx(6), _ctx(7)
    ref_b = _engine(params).generate(
        "m1", ctx_b, SamplingParams(max_tokens=8)).result()
    probe = _engine(params).generate(
        "m0", ctx_a, SamplingParams(max_tokens=8)).result()

    eos_tok = int(probe[1])
    cut = probe.tolist().index(eos_tok) + 1    # steps until ra dies
    assert cut < 8

    eng = _engine(params)
    ra = eng.generate("m0", ctx_a, SamplingParams(
        max_tokens=8, eos_token_id=eos_tok))
    rb = eng.generate("m1", ctx_b, SamplingParams(max_tokens=8))
    eng.run()
    assert ra.finish_reason == FINISH_EOS and len(ra.tokens) == cut
    np.testing.assert_array_equal(rb.result(), ref_b)
    # `cut` joint steps + (8 - cut) solo steps: the dead sequence stopped
    # consuming budget/batch slots the step after its EOS
    assert eng.stats.decode_steps == 8
    assert eng.stats.decode_tokens == 2 * cut + (8 - cut)


# ======================================================================
# streaming


def test_streaming_iterator_callback_and_latency_capture(params):
    eng = _engine(params)
    seen = []
    out = eng.generate("m0", _ctx(8), SamplingParams(max_tokens=5),
                       stream_callback=lambda ro, t: seen.append(t))
    assert out.tokens == [] and out.ttft is None
    streamed = list(out)                       # iterator drives the engine
    assert out.finished and out.finish_reason == FINISH_LENGTH
    assert streamed == out.tokens == seen and len(streamed) == 5
    assert out.ttft is not None and out.ttft >= 0
    assert len(out.token_times) == 5
    assert len(out.inter_token_latencies()) == 4
    # late callback replays the already-streamed prefix
    replay = []
    out.add_callback(lambda ro, t: replay.append(t))
    assert replay == streamed
    np.testing.assert_array_equal(out.result(), streamed)


# ======================================================================
# abort: page accounting at every lifecycle stage


def _free_baseline(eng):
    eng.block_pool.check_invariants()
    return eng.block_pool.free_count


def test_abort_queued_request(params):
    eng = _engine(params, chunked=True, chunk_size=5, token_budget=16)
    base = _free_baseline(eng)
    out = eng.generate("m0", _ctx(9), SamplingParams(max_tokens=4))
    assert eng.abort(out) is True
    assert out.finished and out.finish_reason == FINISH_ABORT
    assert not eng.scheduler.has_work()
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()
    assert eng.abort(out) is False             # idempotent
    with pytest.raises(KeyError, match="aborted"):
        eng.result(out.request_id)


def test_abort_mid_chunk_prefill(params):
    """Abort while the prompt is partially prefilled: computed tail pages
    are dropped, the cached-prefix refs return, pool to baseline."""
    eng = _engine(params, chunked=True, chunk_size=5, token_budget=8)
    base = _free_baseline(eng)
    victim = eng.generate("m0", _ctx(10, 40), SamplingParams(max_tokens=4))
    other = eng.generate("m1", _ctx(11), SamplingParams(max_tokens=4))
    eng.step()
    eng.step()                                  # victim mid-prefill
    assert any(r.rid == victim.request_id and 0 < r.done < r.n
               for r in eng.scheduler.prefilling)
    assert victim.abort() is True
    ref = _engine(params).generate(
        "m1", _ctx(11), SamplingParams(max_tokens=4)).result()
    np.testing.assert_array_equal(other.result(), ref)   # survivor unharmed
    eng.end_session(other.session_id)
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0


def test_abort_held_under_pool_exhaustion(params):
    """A request HELD by backpressure (its chunk cannot obtain pages) can be
    aborted; its partial pages free, unblocking nothing less than the pool's
    baseline, while the running request completes."""
    eng = _engine(params, num_pages=9, chunked=True, chunk_size=6,
                  token_budget=8)
    base = _free_baseline(eng)
    ra = eng.generate("m0", _ctx(12, 18), SamplingParams(max_tokens=10))
    rb = eng.generate("m1", _ctx(13, 18), SamplingParams(max_tokens=10))
    stalled = None
    for _ in range(40):
        eng.step()
        if eng.scheduler.stats.stalls and any(
                r.rid == rb.request_id for r in eng.scheduler.prefilling):
            stalled = rb
            break
        if not eng.scheduler.has_work():
            break
    assert stalled is not None, "workload never hit backpressure"
    assert stalled.abort() is True
    eng.run()
    assert ra.finished and ra.finish_reason == FINISH_LENGTH
    eng.end_session(ra.session_id)
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()


def test_abort_while_decoding(params):
    eng = _engine(params)
    base = _free_baseline(eng)
    out = eng.generate("m0", _ctx(14), SamplingParams(max_tokens=12))
    eng.step()
    eng.step()
    assert 0 < len(out.tokens) < 12
    partial = list(out.tokens)
    assert out.abort() is True
    assert out.finish_reason == FINISH_ABORT
    assert out.tokens == partial               # stream frozen at abort point
    np.testing.assert_array_equal(out.result(), partial)   # partial, no hang
    assert not eng.scheduler.has_work()
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0


def test_abort_cached_prefix_request_returns_baseline(params):
    """Abort page accounting with the GLOBAL radix tree holding references:
    a request whose prompt hit the automatic prefix cache refs shared cached
    pages; aborting it mid-chunk or mid-decode returns the free-page count
    exactly to the post-warm baseline, and the cached prefix stays servable."""
    eng = _engine(params, chunked=True, chunk_size=5, token_budget=16)
    prefix = _ctx(40, 4 * PAGE)
    # warm: a PLAIN generate (no SharedContext) publishes the prefix in the
    # engine-global tree; its ephemeral session auto-releases on finish
    eng.generate("m0", prefix, SamplingParams(max_tokens=2)).result()
    base = _free_baseline(eng)
    assert eng.stats()["prefix_nodes"] >= 4

    # (a) abort mid-chunk: cached-prefix refs return to the LRU cache, the
    # partially-computed tail pages are dropped
    victim = eng.generate("m0", prefix + _ctx(41, 12),
                          SamplingParams(max_tokens=4))
    eng.step()
    r = next(r for r in eng.scheduler.prefilling
             if r.rid == victim.request_id)
    assert r.alloc.cached_tokens == 4 * PAGE   # hit with NO shared session
    assert victim.abort() is True
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()

    # (b) abort while decoding: handoff refs on the cached prefix unwind too
    out = eng.generate("m0", prefix + _ctx(42, 5),
                       SamplingParams(max_tokens=12))
    while not out.tokens:
        eng.step()
    assert out.abort() is True
    eng.run()
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0

    # (c) the aborts did not poison the tree: a fresh request still hits
    out2 = eng.generate("m0", prefix + _ctx(43, 7),
                        SamplingParams(max_tokens=3))
    out2.result()
    s = eng.stats()
    assert s["prefix_hit_tokens"] >= 3 * 4 * PAGE    # (a), (b) and (c) hit
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()


def test_abort_cached_prefix_eager_returns_baseline(params):
    """Same baseline guarantee on the eager path: a decoding request whose
    prefill fully reused the published prefix aborts back to baseline."""
    eng = _engine(params)
    prefix = _ctx(44, 3 * PAGE)
    eng.generate("m0", prefix, SamplingParams(max_tokens=2)).result()
    base = _free_baseline(eng)
    out = eng.generate("m1", prefix, SamplingParams(max_tokens=12))
    eng.step()
    assert out.abort() is True
    assert eng.block_pool.free_count == base
    eng.block_pool.check_invariants()
    assert eng.stats()["prefix_hit_tokens"] >= 3 * PAGE


# ======================================================================
# shared contexts


def test_shared_context_end_to_end(params):
    """One prefilled prefix, many models: the prefix is computed ONCE, every
    generate reuses it (the paper's execution pattern), extend grows it
    across turns, close releases the pages."""
    eng = _engine(params, num_pages=128)
    prefix = _ctx(15, 2 * PAGE)
    refs = {}
    for mid in ("m0", "m1"):
        refs[mid] = _legacy_invoke(_engine(params), 0, prefix, mid, 4)

    with eng.shared_context(prefix) as ctx:
        assert eng.stats.prefill_tokens_computed == len(prefix)  # warmed
        outs = {mid: ctx.generate(mid, params=SamplingParams(max_tokens=4))
                for mid in ("m0", "m1")}
        eng.run()
        for mid, out in outs.items():
            np.testing.assert_array_equal(out.result(), refs[mid])
        # prefix computed once; both generates fully reused it
        assert eng.stats.prefill_tokens_computed == len(prefix)
        assert eng.stats.prefill_tokens_reused >= 2 * len(prefix)

        ctx.extend(outs["m0"].tokens)
        out2 = ctx.generate("m1", params=SamplingParams(max_tokens=3))
        ref2 = _legacy_invoke(_engine(params), 0,
                              prefix + outs["m0"].tokens, "m1", 3)
        np.testing.assert_array_equal(out2.result(), ref2)
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0    # close released the session


def test_shared_context_chunked_with_tails(params):
    """SharedContext on the chunked scheduler, with request-private tails:
    tails never join the shared prefix, prefix pages are shared page-
    granularly."""
    eng = _engine(params, chunked=True, chunk_size=6, token_budget=16,
                  num_pages=128)
    prefix = _ctx(16, 3 * PAGE)
    tails = {"m0": _ctx(17, 5), "m1": _ctx(18, 7)}
    refs = {mid: _legacy_invoke(_engine(params), 0, prefix + t, mid, 3)
            for mid, t in tails.items()}
    with eng.shared_context(prefix) as ctx:
        outs = {mid: ctx.generate(mid, t, SamplingParams(max_tokens=3))
                for mid, t in tails.items()}
        eng.run()
        for mid, out in outs.items():
            np.testing.assert_array_equal(out.result(), refs[mid])
        assert ctx.tokens == prefix            # tails stayed private
    assert eng.stats.prefill_tokens_reused >= 2 * len(prefix)
    eng.block_pool.check_invariants()


def test_ephemeral_session_cleanup(params):
    """generate() without a session runs in an engine-owned one-shot
    session, released automatically on finish — no caller end_session."""
    eng = _engine(params)
    eng.generate("m0", _ctx(19), SamplingParams(max_tokens=3)).result()
    assert all(not w.sessions for w in eng.prefill_workers)
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0


# ======================================================================
# legacy shim


def test_legacy_surface_warns_and_stays_token_identical(params):
    new = _engine(params).generate(
        "m0", _ctx(20), SamplingParams(max_tokens=5)).result()
    eng = _engine(params)
    with pytest.warns(DeprecationWarning, match="submit.*deprecated"):
        rid = eng.submit(0, _ctx(20), "m0", gen_tokens=5)
    eng.run()
    np.testing.assert_array_equal(eng.result(rid), new)
    eng2 = _engine(params)
    with pytest.warns(DeprecationWarning, match="invoke.*deprecated"):
        old = eng2.invoke(0, _ctx(20), "m0", gen_tokens=5)
    np.testing.assert_array_equal(old, new)


# ======================================================================
# chunk block-table bucketing (ROADMAP open item)


def test_chunk_block_table_bucketing_bounds_retraces():
    """CHUNK block tables are padded to the next power of two, so the jitted
    chunk step retraces O(log pages) times over a long prefill instead of
    once per page of table growth."""
    cfg = ModelConfig(name="api-bucket", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=64, dtype="float32")
    base = init_params(cfg, jax.random.PRNGKey(0))
    decs = {"m0": init_params(cfg, jax.random.PRNGKey(10))}
    eng = LocalDisaggEngine(cfg, base, decs, num_pages=64, page_size=4,
                            chunked=True, chunk_size=8, token_budget=8)
    before = CHUNK_TRACES.get(cfg, 0)
    out = eng.generate("m0", _ctx(21, 96), SamplingParams(max_tokens=2))
    out.result()                               # 96 tokens -> 24 pages
    chunks = eng.scheduler.stats.chunks
    traces = CHUNK_TRACES.get(cfg, 0) - before
    assert chunks >= 12                        # really was chunked
    # buckets hit: npages in {2,4,8,16,32} (+ the final ragged chunk S) —
    # far fewer traces than chunks; unbucketed tables would retrace ~every
    # chunk that grows the table
    assert traces <= 7, (traces, chunks)
