"""The paged KV data plane: bit-identity vs the dense engine, refcounted
zero-copy handoff, page-aligned partial prefill, continuous batching."""
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.kvcache.manager import kv_bytes_per_token
from repro.models import init_params
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="paged-eng", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                  dtype="float32")
PAGE = 8


def _params():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(2)}
    return base, decs


def _engine(base, decs, **kw):
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    return LocalDisaggEngine(CFG, base, decs, **kw)


def test_paged_matches_dense_engine_bitwise():
    """Greedy tokens from the paged data plane == the dense per-session
    engine, across agents and growing multi-turn context."""
    base, decs = _params()
    paged = _engine(base, decs)
    dense = LocalDisaggEngine(CFG, base, decs, capacity=256, paged=False)
    assert paged.paged and not dense.paged

    rng = np.random.default_rng(0)
    ctx = list(rng.integers(4, 60, size=19))      # off page boundary
    for turn in range(2):
        for mid in ("m0", "m1"):
            ctx += list(rng.integers(4, 60, size=5))
            got = paged.invoke(0, ctx, mid, gen_tokens=4)
            ref = dense.invoke(0, ctx, mid, gen_tokens=4)
            np.testing.assert_array_equal(got, ref)
            ctx += list(got)
    assert paged.stats.prefill_tokens_reused > 0
    assert paged.stats.cow_page_copies > 0        # partial tails were cloned


def test_zero_copy_handoff_refcounts_and_bytes():
    """Handoff moves block-table metadata only; prefix pages are freed only
    when the LAST holder (session or decode sequence) releases them."""
    base, decs = _params()
    eng = _engine(base, decs)
    ctx = list(range(4, 4 + 20))                  # 20 tokens: 2 full + partial
    r0 = eng.submit(0, ctx, "m0", gen_tokens=3)
    r1 = eng.submit(0, ctx, "m1", gen_tokens=3)

    sess = eng.prefill_workers[0].sessions[0]
    full_page = sess.block_table[0]
    # holders: session alloc + two decode sequences
    assert eng.block_pool.refcount(full_page) == 3
    # partial tail page was CoW-cloned, not shared for writing
    assert eng.stats.cow_page_copies == 2

    # wire bytes: block-table metadata only, orders below a dense copy
    dense_bytes = kv_bytes_per_token(CFG) * len(ctx)
    assert 0 < eng.stats.handoff_bytes < dense_bytes
    assert eng.stats.handoff_bytes == 2 * (4 * 3 + 16)   # 3-page tables

    eng.run()
    np.testing.assert_array_equal(eng.result(r0).shape, (3,))
    np.testing.assert_array_equal(eng.result(r1).shape, (3,))
    # decoders released; the session still pins its pages
    assert eng.block_pool.refcount(full_page) == 1
    eng.end_session(0)
    assert eng.block_pool.refcount(full_page) == 0       # CACHED, evictable
    eng.block_pool.check_invariants()
    assert eng.block_pool.active_count == 0


def test_partial_prefill_writes_only_tail_pages():
    """Extending a session recomputes/rewrites only pages past the cached
    page-aligned prefix; resident full pages are untouched."""
    base, decs = _params()
    eng = _engine(base, decs)
    w = eng.prefill_workers[0]
    rng = np.random.default_rng(1)
    ctx = list(rng.integers(4, 60, size=20))      # pages: 2 full + 1 partial
    bt1, _ = w.prefill(0, ctx)
    snap_k = {g: np.asarray(a) for g, a in eng.kvpool.k_groups.items()}

    ctx2 = ctx + list(rng.integers(4, 60, size=8))       # 28 tokens
    bt2, _ = w.prefill(0, ctx2)
    assert bt2[:2] == bt1[:2]                     # full pages reused in place
    assert eng.stats.prefill_tokens_reused == 2 * PAGE

    fresh = set(bt2[2:])
    assert fresh.isdisjoint(bt1[:2])
    for g, a in eng.kvpool.k_groups.items():
        now = np.asarray(a)
        for p in range(1, eng.block_pool.num_blocks + 1):   # usable page ids
            same = np.array_equal(now[:, p], snap_k[g][:, p])
            if p in fresh:
                assert not same, f"tail page {p} not written"
            else:
                assert same, f"page {p} touched outside the tail span"
    eng.end_session(0)


def test_continuous_batching_matches_sequential():
    """4 sequences of one decode model advance as a single batched step per
    token, and produce the same greedy tokens as isolated invokes."""
    base, decs = _params()
    rng = np.random.default_rng(2)
    ctxs = [list(rng.integers(4, 60, size=12 + 3 * i)) for i in range(4)]

    eng = _engine(base, decs)
    rids = [eng.submit(sid, ctx, "m0", gen_tokens=4)
            for sid, ctx in enumerate(ctxs)]
    eng.run()
    batched = [eng.result(r) for r in rids]
    assert eng.stats.decode_batch_mean == 4.0     # all steps fully batched

    ref_eng = _engine(base, decs)
    for sid, (ctx, got) in enumerate(zip(ctxs, batched)):
        ref = ref_eng.invoke(sid, ctx, "m0", gen_tokens=4)
        np.testing.assert_array_equal(got, ref)
