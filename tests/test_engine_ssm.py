"""The engine's shared 'cache' generalizes to SSM state (DESIGN.md §4):
run the REAL disaggregated engine on an attention-free Mamba-2 reduced
config — the handoff carries SSD+conv state, not KV — and assert
bit-identical generations vs full-recompute references."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import LocalDisaggEngine
from tests.test_engine_integration import _reference_generate


def test_engine_on_mamba2_state_handoff():
    cfg = get_config("mamba2-780m").reduced(vocab=64)
    base = init_params(cfg, jax.random.PRNGKey(0))
    decs = {"m0": init_params(cfg, jax.random.PRNGKey(1)),
            "m1": init_params(cfg, jax.random.PRNGKey(2))}
    eng = LocalDisaggEngine(cfg, base, decs, capacity=128)
    rng = np.random.default_rng(3)
    ctx = list(rng.integers(4, 60, size=20))
    for mid in ("m0", "m1", "m0"):
        ctx += list(rng.integers(4, 60, size=5))
        gen = eng.invoke(0, ctx, mid, gen_tokens=4)
        ref = _reference_generate(cfg, base, decs[mid], ctx, 4)
        np.testing.assert_array_equal(gen, ref)
        ctx += list(gen)
    # constant-size state: reuse accounting still works at token granularity
    assert eng.stats.prefill_tokens_reused > 0
