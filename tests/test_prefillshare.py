"""PrefillShare core semantics (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.prefillshare import (base_prefill, cache_conditioned_loss,
                                     cache_schema, full_ft_loss, mix_caches,
                                     model_fingerprint)
from repro.kvcache.handoff import HandoffChannel, SchemaMismatch
from repro.models import init_params

CFG = ModelConfig(name="t", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")
KEY = jax.random.PRNGKey(0)


def _params(seed=0):
    return init_params(CFG, jax.random.PRNGKey(seed))


def _batch(B=2, Sp=8, St=6):
    ks = jax.random.split(KEY, 3)
    return (jax.random.randint(ks[0], (B, Sp), 4, 60),
            jax.random.randint(ks[1], (B, St), 4, 60),
            jax.random.randint(ks[2], (B, St), 4, 60),
            jnp.ones((B, St), jnp.float32))


def test_gradients_do_not_touch_base():
    """Eq. 7: stop_grad on C_base — d loss / d θ_base must be exactly zero."""
    base, dec = _params(0), _params(1)
    prompt, ti, to, m = _batch()

    def loss_wrt_base(bp):
        loss, _ = cache_conditioned_loss(CFG, dec, bp, prompt, ti, to, m)
        return loss

    g = jax.grad(loss_wrt_base)(base)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert total == 0.0


def test_gradients_flow_to_decoder():
    base, dec = _params(0), _params(1)
    prompt, ti, to, m = _batch()

    def loss_wrt_dec(dp):
        loss, _ = cache_conditioned_loss(CFG, dp, base, prompt, ti, to, m)
        return loss

    g = jax.grad(loss_wrt_dec)(dec)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert total > 0.0


def test_share_ratio_endpoints():
    """ratio=1 == pure base cache; ratio=0 == pure self cache."""
    base, dec = _params(0), _params(1)
    prompt, ti, to, m = _batch()
    l1, _ = cache_conditioned_loss(CFG, dec, base, prompt, ti, to, m,
                                   share_ratio=1.0)
    _, cb = base_prefill(CFG, base, prompt, cache_len=20)
    _, cs = base_prefill(CFG, dec, prompt, cache_len=20)
    mixed_full = mix_caches(CFG, cb, cs, 1.0)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()), mixed_full, cb))
    mixed_none = mix_caches(CFG, cb, cs, 0.0)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()), mixed_none, cs))
    l0, _ = cache_conditioned_loss(CFG, dec, base, prompt, ti, to, m,
                                   share_ratio=0.0)
    assert abs(float(l1) - float(l0)) > 1e-6  # different conditioning


def test_mix_ratio_layer_counts():
    base, dec = _params(0), _params(1)
    prompt, *_ = _batch()
    _, cb = base_prefill(CFG, base, prompt, cache_len=16)
    _, cs = base_prefill(CFG, dec, prompt, cache_len=16)
    mixed = mix_caches(CFG, cb, cs, 0.5)
    # first 2 of 4 layers from base
    kb = cb["groups"]["pos0"]["k"]
    km = mixed["groups"]["pos0"]["k"]
    ks = cs["groups"]["pos0"]["k"]
    assert bool((km[0] == kb[0]).all()) and bool((km[1] == kb[1]).all())
    assert not bool((km[2] == kb[2]).all())
    assert bool((km[2] == ks[2]).all())


def test_partial_prefill_extends_cache():
    """§3.3: extend-only prefill equals one-shot prefill."""
    base = _params(0)
    prompt, *_ = _batch(B=2, Sp=12)
    out_full, c_full = base_prefill(CFG, base, prompt, cache_len=16)
    _, c1 = base_prefill(CFG, base, prompt[:, :8], cache_len=16)
    out2, c2 = base_prefill(CFG, base, prompt[:, 8:], cache_len=16, cache=c1,
                            pos=jnp.full((2,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(c_full), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_full_ft_loss_runs():
    p = _params(0)
    prompt, ti, to, m = _batch()
    loss, _ = full_ft_loss(CFG, p, prompt, ti, to, m)
    assert jnp.isfinite(loss)


def test_schema_compat_and_handoff_guard():
    base, other = _params(0), _params(1)
    s1 = cache_schema(CFG, base, 128)
    s2 = cache_schema(CFG, base, 256)      # different len, same producer: OK
    assert s1.compatible_with(s2)
    s3 = cache_schema(CFG, other, 128)     # different base: incompatible
    assert not s1.compatible_with(s3)
    with pytest.raises(SchemaMismatch):
        HandoffChannel.check(s1, s3)
    assert model_fingerprint(CFG, base) != model_fingerprint(CFG, other)


def test_handoff_plan_costs():
    ch = HandoffChannel(CFG, link_gbps=50.0, n_links=2)
    p1 = ch.plan(1000)
    p2 = ch.plan(2000)
    assert p2.bytes > p1.bytes and p2.seconds > p1.seconds
    staged = ch.plan(2000, decode_hbm_free_bytes=0)
    assert staged.staged and staged.seconds > p2.seconds
