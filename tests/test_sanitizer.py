"""PoolSanitizer: sanitize=True is token-bit-identical to sanitize=False,
and every seeded corruption trips with a precise diagnostic."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kvcache.blocks import BlockPool
from repro.kvcache.radix import Node, PrefixIndex
from repro.kvcache.sanitize import (SanitizedKVPool, SanitizerError,
                                    check_index, check_pool)
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="sanitize-eng", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab_size=64, dtype="float32")
PAGE = 8


def _params():
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(2)}
    return base, decs


def _engine(base, decs, **kw):
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PAGE)
    return LocalDisaggEngine(CFG, base, decs, **kw)


def _start_decode(eng, tokens=None, max_tokens=6):
    """Admit one request and step until it reaches the decode plane."""
    h = eng.generate("m0", tokens or list(range(1, 12)),
                     SamplingParams(max_tokens=max_tokens))
    for _ in range(32):
        eng.scheduler.step()
        if eng.scheduler.active:
            return h
    raise AssertionError("request never reached decode")


# ======================================================================
# bit-identity
# ======================================================================

def test_sanitize_run_is_token_bit_identical():
    base, decs = _params()
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(4, 60, size=n)) for n in (9, 21, 9, 5)]

    def run(sanitize):
        eng = _engine(base, decs, chunked=True, chunk_size=PAGE,
                      token_budget=32, sanitize=sanitize)
        hs = [eng.generate(f"m{i % 2}", p, SamplingParams(max_tokens=5))
              for i, p in enumerate(prompts)]
        eng.scheduler.run()
        return [h.result().tolist() for h in hs], eng

    ref, _ = run(False)
    got, eng = run(True)
    assert got == ref
    assert eng.sanitizer is not None and eng.sanitizer.checks > 0
    assert isinstance(eng.kvpool, SanitizedKVPool)


def test_sanitize_requires_paged_plane():
    base, decs = _params()
    with pytest.raises(ValueError, match="paged"):
        LocalDisaggEngine(CFG, base, decs, paged=False, sanitize=True)


# ======================================================================
# seeded corruptions -> precise diagnostics
# ======================================================================

def test_refcount_corruption_trips():
    base, decs = _params()
    eng = _engine(base, decs, chunked=True, sanitize=True)
    _start_decode(eng)
    s = eng.scheduler.active[0]
    bid = s.shared_blocks[0]
    eng.block_pool._refcount[bid] += 1           # phantom reference
    with pytest.raises(SanitizerError, match=f"refcount mismatch on page "
                                             f"{bid}"):
        eng.scheduler.step()


def test_leaked_reference_trips():
    base, decs = _params()
    eng = _engine(base, decs, chunked=True, sanitize=True)
    _start_decode(eng)
    leaked = eng.block_pool.alloc(1)[0]          # held by NO engine structure
    with pytest.raises(SanitizerError,
                       match=f"page {leaked} is ACTIVE .* NO engine "
                             f"structure"):
        eng.scheduler.step()


def test_sentinel_in_live_table_trips():
    base, decs = _params()
    eng = _engine(base, decs, chunked=True, sanitize=True)
    _start_decode(eng)
    eng.scheduler.active[0].block_table[0] = BlockPool.SENTINEL
    with pytest.raises(SanitizerError, match="sentinel page 0 appears in "
                                             "the live block table"):
        eng.scheduler.step()


def test_stale_index_entry_trips():
    base, decs = _params()
    eng = _engine(base, decs, chunked=True, sanitize=True)
    _start_decode(eng)
    idx = eng.prefix_index
    free_bid = eng.block_pool._free[-1]
    node = Node(key=(99,) * PAGE, block_id=free_bid, parent=idx.root)
    idx.root.children[node.key] = node
    idx._by_block[free_bid] = node               # index points at a FREE page
    with pytest.raises(SanitizerError,
                       match=f"block {free_bid} but the pool has it FREE"):
        eng.scheduler.step()


def test_leaked_relay_page_trips_with_relay_naming():
    """Relay-published pages are first-class in the step census: a published
    page seeded ACTIVE with NO engine holder must trip with a diagnostic
    NAMING relay publication as the holder class (not the generic leak
    message) — the PR 8 ROADMAP instruction for new page owners."""
    base, _ = _params()
    eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE,
                            chunked=True, sanitize=True)
    eng.models.register("m_base", base)       # KV path == base: may publish
    prompt = list(range(1, 1 + 2 * PAGE))
    eng.generate("m_base", prompt,
                 SamplingParams(max_tokens=PAGE + 2)).result()
    assert eng.stats()["relay_pages_published"] > 0
    bid = next(bid for bid, nd in eng.prefix_index._by_block.items()
               if nd.provenance == "relay")
    eng.scheduler.step()                      # clean census first
    eng.block_pool.ref([bid])                 # seeded leak: ACTIVE, no holder
    with pytest.raises(SanitizerError, match=f"page {bid} is ACTIVE .* "
                                             f"holder: relay publication"):
        eng.scheduler.step()


def test_relay_refcount_mismatch_tags_relay_page():
    """A refcount corruption on a page that happens to be relay-published is
    tagged as such in the mismatch diagnostic."""
    base, _ = _params()
    eng = LocalDisaggEngine(CFG, base, num_pages=64, page_size=PAGE,
                            chunked=True, sanitize=True)
    eng.models.register("m_base", base)
    prompt = list(range(1, 1 + 2 * PAGE))
    out = eng.generate("m_base", prompt,
                       SamplingParams(max_tokens=PAGE + 2)).result()
    relay_bid = next(bid for bid, nd in eng.prefix_index._by_block.items()
                     if nd.provenance == "relay")
    # a follower whose prompt EXTENDS the published stream holds the relay
    # page as cached prefix while decoding; corrupt its refcount mid-flight
    eng.generate("m_base", prompt + [2] + [int(t) for t in out],
                 SamplingParams(max_tokens=4))
    for _ in range(32):
        eng.scheduler.step()
        if eng.scheduler.active:
            break
    assert any(relay_bid in s.shared_blocks for s in eng.scheduler.active)
    eng.block_pool._refcount[relay_bid] += 1
    with pytest.raises(SanitizerError, match="relay-published page"):
        eng.scheduler.step()


# ======================================================================
# donation poisoning
# ======================================================================

def test_use_after_donation_trips():
    base, decs = _params()
    eng = _engine(base, decs, sanitize=True)
    stale = eng.kvpool.decode_state()
    g = next(iter(stale["groups"]))
    eng.kvpool.absorb_decode_state(eng.kvpool.decode_state())
    with pytest.raises(SanitizerError, match="use-after-donation"):
        _ = stale["groups"][g]["k"].shape
    with pytest.raises(SanitizerError, match="use-after-donation"):
        np.asarray(stale["groups"][g]["v"])


def test_stale_decode_cache_trips():
    base, decs = _params()
    eng = _engine(base, decs, sanitize=True)
    bt = np.zeros((1, 2), np.int32)
    stale = eng.kvpool.make_decode_cache(bt)
    g = next(iter(stale["groups"]))
    eng.kvpool.absorb_decode_cache(eng.kvpool.make_decode_cache(bt))
    with pytest.raises(SanitizerError, match="use-after-donation"):
        _ = stale["groups"][g]["k_pages"][0]


def test_absorbed_tree_itself_is_never_poisoned():
    """Round-tripping the handed-out dict through absorb (legal off-TPU
    no-op) must keep the pool's buffers real arrays."""
    base, decs = _params()
    eng = _engine(base, decs, sanitize=True)
    state = eng.kvpool.decode_state()
    eng.kvpool.absorb_decode_state(state)
    for g, arr in eng.kvpool.k_groups.items():
        assert hasattr(arr, "shape")             # a real array, not a trap


def test_copy_page_retires_outstanding_state():
    base, decs = _params()
    eng = _engine(base, decs, sanitize=True)
    stale = eng.kvpool.decode_state()
    g = next(iter(stale["groups"]))
    (bid,) = eng.block_pool.alloc(1)
    (dst,) = eng.block_pool.alloc(1)
    eng.kvpool.copy_page(bid, dst)               # donated pool update on TPU
    with pytest.raises(SanitizerError, match="copy_page"):
        _ = stale["groups"][g]["k"].shape


# ======================================================================
# standalone checkers (no engine)
# ======================================================================

def test_check_pool_diagnoses_direct_corruption():
    p = BlockPool(8, 4)
    check_pool(p)                                # fresh pool is clean
    blocks = p.alloc(2)
    check_pool(p)
    p._refcount[blocks[0]] = -1
    with pytest.raises(SanitizerError, match="negative"):
        check_pool(p)
    p._refcount[blocks[0]] = 1
    p._free.append(blocks[1])                    # active AND free
    with pytest.raises(SanitizerError, match="also in the free"):
        check_pool(p)
    p._free.pop()
    p._refcount[BlockPool.SENTINEL] = 1
    with pytest.raises(SanitizerError, match="sentinel page 0"):
        check_pool(p)


def test_check_index_structural_and_residency():
    pool = BlockPool(8, 2)
    idx = PrefixIndex(2)
    pool.add_evict_callback(idx.remove_block)
    blocks = pool.alloc(2)
    idx.insert([1, 2, 3, 4], blocks)
    check_index(idx, pool)
    pool.unref(blocks)                           # CACHED: still resident
    check_index(idx, pool)
    pool.drop(list(blocks))                      # FREE without eviction cb
    with pytest.raises(SanitizerError, match="FREE"):
        check_index(idx, pool)
