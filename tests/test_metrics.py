"""Observability subsystem (serving/metrics.py): histogram math vs numpy,
trace-span ordering + abort paths, disabled-mode guarantees, and the
Prometheus exposition + lint."""
import math
import tracemalloc

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine
from repro.serving.metrics import (SPAN_ABORTED, SPAN_CHUNK,
                                   SPAN_FIRST_TOKEN, SPAN_FINISHED,
                                   SPAN_HANDOFF, SPAN_QUEUED, SPAN_ROUTED,
                                   SPAN_TOKEN, Histogram, MetricsRegistry,
                                   NullGauge, NullHistogram, lint_prometheus)

CFG = ModelConfig(name="metrics-eng", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab_size=64, dtype="float32")


def _engine(**kw):
    kw.setdefault("num_pages", 128)
    kw.setdefault("page_size", 8)
    eng = LocalDisaggEngine(CFG, init_params(CFG, jax.random.PRNGKey(0)),
                            **kw)
    eng.models.register("m0", init_params(CFG, jax.random.PRNGKey(7)))
    return eng


# ----------------------------------------------------------------------
# histogram math


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
def test_histogram_percentiles_vs_numpy(dist):
    """Interpolated log-bucket percentiles track numpy quantiles within the
    bucket growth factor (docstring bound: relative error <= growth - 1)."""
    rng = np.random.default_rng(0)
    xs = {"lognormal": rng.lognormal(-2.0, 1.5, size=5000),
          "uniform": rng.uniform(1e-4, 10.0, size=5000),
          "exponential": rng.exponential(0.05, size=5000)}[dist]
    growth = 1.25
    h = Histogram("h", lo=1e-6, hi=4e3, growth=growth)
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.sum == pytest.approx(float(xs.sum()), rel=1e-9)
    for q in (50, 90, 95, 99):
        est, ref = h.percentile(q), float(np.percentile(xs, q))
        assert abs(est - ref) <= (growth - 1.0) * ref + 1e-12, \
            (dist, q, est, ref)


def test_histogram_edge_cases():
    h = Histogram("h")
    assert math.isnan(h.percentile(50))
    assert math.isnan(h.mean)
    h.observe(0.5)
    # one sample: every percentile is that sample (min/max clamp)
    assert h.percentile(0) == h.percentile(50) == h.percentile(100) == 0.5
    # below-lo and above-hi samples land in the edge buckets, still counted
    h.observe(1e-9)
    h.observe(1e6)
    assert h.count == 3
    assert h.percentile(100) == 1e6
    snap = h.snapshot()
    assert snap["count"] == 3 and snap["min"] == 1e-9 and snap["max"] == 1e6


def test_histogram_cumulative_buckets_monotone():
    rng = np.random.default_rng(1)
    h = Histogram("h")
    for x in rng.lognormal(0.0, 2.0, size=1000):
        h.observe(float(x))
    buckets = h.cumulative_buckets()
    assert math.isinf(buckets[-1][0])          # +Inf bucket always present
    assert buckets[-1][1] == h.count           # cumulative total = count
    cums = [c for _, c in buckets]
    assert cums == sorted(cums)                # non-decreasing


# ----------------------------------------------------------------------
# registry + disabled mode


def test_registry_typed_factories_and_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("x_total")
    assert reg.counter("x_total") is c         # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("x_total")                   # same name, different kind
    g = reg.gauge("g", labels={"k": "a"})
    g2 = reg.gauge("g", labels={"k": "b"})
    assert g is not g2                         # labeled series are distinct


def test_disabled_registry_null_singletons_counters_real():
    reg = MetricsRegistry(enabled=False)
    h1 = reg.histogram("h1")
    h2 = reg.histogram("h2")
    assert isinstance(h1, NullHistogram) and h1 is h2   # shared singleton
    assert isinstance(reg.gauge("g"), NullGauge)
    assert math.isnan(h1.percentile(95))
    # counters stay REAL: the legacy engine.stats() surface runs on them
    c = reg.counter("c_total")
    c.inc(3)
    assert reg.counter("c_total").value == 3


def test_disabled_observe_is_allocation_free():
    """The decode hot loop's would-be samples must not allocate when
    metrics are off: fixed-arity no-op methods on shared singletons."""
    reg = MetricsRegistry(enabled=False)
    h, g = reg.histogram("h"), reg.gauge("g")
    v = 0.125
    h.observe(v)                               # warm up any lazy state
    g.set(v)
    spins = [None] * 1000                      # preallocated loop carrier:
    tracemalloc.start()                        # the measured region must
    try:                                       # itself allocate nothing
        before = tracemalloc.take_snapshot()
        for _ in spins:
            h.observe(v)
            g.set(v)
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    leaked = sum(s.size_diff for s in after.compare_to(before, "lineno")
                 if "test_metrics" in (s.traceback[0].filename or ""))
    assert leaked == 0, f"disabled-mode sampling allocated {leaked} bytes"


def test_disabled_engine_bit_identical_tokens():
    """metrics=False must not perturb decode: greedy streams match the
    metrics=True engine token for token."""
    rng = np.random.default_rng(2)
    ctxs = [list(rng.integers(4, 60, size=18 + i)) for i in range(3)]
    streams = []
    for metrics in (True, False):
        eng = _engine(metrics=metrics)
        outs = [eng.generate("m0", c, SamplingParams(max_tokens=6))
                for c in ctxs]
        eng.run()
        streams.append([list(o.tokens) for o in outs])
        if not metrics:
            snap = eng.metrics()
            assert snap["histograms"] == {}    # nothing registered
            assert snap["traces"] == []
    assert streams[0] == streams[1]


# ----------------------------------------------------------------------
# lifecycle traces


def test_trace_span_ordering_and_ttft():
    eng = _engine(chunked=True, chunk_size=8, token_budget=64)
    rng = np.random.default_rng(3)
    out = eng.generate("m0", list(rng.integers(4, 60, size=20)),
                       SamplingParams(max_tokens=5))
    eng.run()
    assert out.finished
    traces = [t for t in eng.metrics_registry.traces()
              if t.rid == out.request_id]
    assert len(traces) == 1
    tr = traces[0]
    assert tr.done
    names = [n for n, _, _ in tr.events]
    # lifecycle vocabulary in causal order (chunk/token repeat)
    for a, b in [(SPAN_QUEUED, SPAN_ROUTED), (SPAN_ROUTED, SPAN_CHUNK),
                 (SPAN_CHUNK, SPAN_HANDOFF), (SPAN_HANDOFF, SPAN_FIRST_TOKEN),
                 (SPAN_FIRST_TOKEN, SPAN_FINISHED)]:
        assert names.index(a) < names.index(b), names
    # first token + one token span per later streamed token
    assert names.count(SPAN_TOKEN) == len(out.tokens) - 1
    # timestamps are monotone through the pipeline
    times = [t for _, t, _ in tr.events]
    assert times == sorted(times)
    # the derived TTFT span is the same clock RequestOutput exposes (the
    # queued span and submit_time are separate perf_counter reads µs apart)
    assert tr.ttft_s == pytest.approx(out.ttft, abs=5e-3)
    # and the registry's TTFT histogram saw exactly this engine's requests
    snap = eng.metrics()["histograms"]["engine_ttft_seconds"]
    assert snap["count"] == 1
    assert snap["min"] <= out.ttft <= snap["max"] + 1e-12


def test_abort_closes_trace_at_every_stage():
    """Abort at queued / prefilling / decoding all terminate the trace with
    an ``aborted`` span; a finished request is not re-terminated."""
    eng = _engine(chunked=True, chunk_size=4, token_budget=16)
    rng = np.random.default_rng(4)
    mk = lambda n: list(rng.integers(4, 60, size=n))

    def trace_of(out):
        (tr,) = [t for t in eng.metrics_registry.traces()
                 if t.rid == out.request_id]
        return tr

    # queued: aborted before any step ran
    q = eng.generate("m0", mk(24), SamplingParams(max_tokens=4))
    assert eng.abort(q)
    assert trace_of(q).events[-1][0] == SPAN_ABORTED
    assert trace_of(q).done

    # prefilling: one step admits + runs a first chunk of the 40-token
    # prompt (chunk_size=4), then abort reclaims mid-prefill
    p = eng.generate("m0", mk(40), SamplingParams(max_tokens=4))
    eng.step()
    assert any(r.rid == p.request_id for r in eng.scheduler.prefilling)
    assert eng.abort(p)
    tr = trace_of(p)
    assert tr.events[-1][0] == SPAN_ABORTED and tr.done
    assert any(n == SPAN_CHUNK for n, _, _ in tr.events)

    # decoding: step until the first token streamed, then abort
    d = eng.generate("m0", mk(12), SamplingParams(max_tokens=8))
    while not d.tokens and not d.finished:
        eng.step()
    assert eng.abort(d)
    tr = trace_of(d)
    assert tr.events[-1][0] == SPAN_ABORTED and tr.done
    assert any(n == SPAN_FIRST_TOKEN for n, _, _ in tr.events)

    # finished: abort is a no-op and must NOT double-terminate the trace
    f = eng.generate("m0", mk(10), SamplingParams(max_tokens=2))
    eng.run()
    assert f.finished
    assert not eng.abort(f)
    tr = trace_of(f)
    assert tr.events[-1][0] == SPAN_FINISHED
    # closed traces ignore later events (idempotent terminal)
    tr.event(SPAN_TOKEN)
    assert tr.events[-1][0] == SPAN_FINISHED


def test_trace_ring_bounded():
    reg = MetricsRegistry(trace_capacity=4)
    for rid in range(10):
        reg.start_trace(rid)
    traces = reg.traces()
    assert len(traces) == 4
    assert [t.rid for t in traces] == [6, 7, 8, 9]   # oldest evicted


# ----------------------------------------------------------------------
# exposition + lint


def test_render_prometheus_lints_clean_and_carries_series():
    eng = _engine()
    rng = np.random.default_rng(5)
    outs = [eng.generate("m0", list(rng.integers(4, 60, size=16 + i)),
                        SamplingParams(max_tokens=4)) for i in range(2)]
    eng.run()
    assert all(o.finished for o in outs)
    text = eng.render_prometheus()
    assert lint_prometheus(text) == []
    for series in ("engine_ttft_seconds_bucket", "engine_ttft_seconds_count",
                   "engine_itl_seconds_sum", "engine_pool_free_pages",
                   "engine_decode_tokens_total"):
        assert series in text, series
    # fn-backed gauges export live values
    free = eng.block_pool.free_count
    assert f"engine_pool_free_pages {free}" in text


def test_lint_prometheus_catches_format_bugs():
    assert lint_prometheus(
        "# HELP a_total ok\n# TYPE a_total counter\na_total 1\n") == []
    # duplicate series
    bad = ("# HELP a_total ok\n# TYPE a_total counter\n"
           "a_total 1\na_total 2\n")
    assert any("duplicate series" in p for p in lint_prometheus(bad))
    # sample without TYPE/HELP headers
    assert any("no TYPE" in p for p in lint_prometheus("b_total 1\n"))
    # non-numeric value
    bad = "# HELP g ok\n# TYPE g gauge\ng NaNopeNope\n"
    assert any("non-numeric" in p for p in lint_prometheus(bad))
    # histogram with no +Inf bucket
    bad = ("# HELP h ok\n# TYPE h histogram\n"
           'h_bucket{le="1.0"} 1\nh_sum 1\nh_count 1\n')
    assert any("+Inf" in p for p in lint_prometheus(bad))
    # non-monotonic cumulative buckets
    bad = ("# HELP h ok\n# TYPE h histogram\n"
           'h_bucket{le="1.0"} 5\nh_bucket{le="+Inf"} 3\n'
           "h_sum 1\nh_count 3\n")
    assert any("decrease" in p for p in lint_prometheus(bad))


def test_stats_surface_still_runs_on_registry_counters():
    """engine.stats() is a view over registry counters — incrementing via
    either surface shows up in both."""
    eng = _engine()
    rng = np.random.default_rng(6)
    out = eng.generate("m0", list(rng.integers(4, 60, size=16)),
                       SamplingParams(max_tokens=3))
    eng.run()
    assert out.finished
    snap = eng.metrics()["counters"]
    assert snap["engine_handoffs_total"] == eng.stats.handoffs > 0
    assert snap["engine_decode_tokens_total"] == eng.stats.decode_tokens > 0
