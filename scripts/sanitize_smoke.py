#!/usr/bin/env python
"""CI smoke: run the chunked engine end-to-end with sanitize=True.

Drives a small multi-model, chunked-prefill workload through
``LocalDisaggEngine(..., sanitize=True)`` so every scheduler step boundary
passes the PoolSanitizer's refcount/sentinel/radix cross-checks, then
asserts the token streams are bit-identical to a sanitize=False run.
Exits non-zero on any sanitizer trip or token divergence.
"""
import sys

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serving.api import SamplingParams
from repro.serving.engine import LocalDisaggEngine

CFG = ModelConfig(name="sanitize-smoke", arch_type="dense", n_layers=2,
                  d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                  vocab_size=64, dtype="float32")


def run(base, decs, prompts, *, sanitize):
    eng = LocalDisaggEngine(CFG, base, num_pages=96, page_size=8,
                            chunked=True, chunk_size=8, token_budget=48,
                            sanitize=sanitize)
    for mid, params in decs.items():
        eng.models.register(mid, params)
    handles = [eng.generate(f"m{i % 2}", p, SamplingParams(max_tokens=6))
               for i, p in enumerate(prompts)]
    eng.scheduler.run()
    return [h.result().tolist() for h in handles], eng


def main() -> int:
    base = init_params(CFG, jax.random.PRNGKey(0))
    decs = {f"m{i}": init_params(CFG, jax.random.PRNGKey(10 + i))
            for i in range(2)}
    rng = np.random.default_rng(7)
    # shared prefixes (radix hits), off-page lengths (CoW tails), and a
    # long prompt (many chunks) — the paths the sanitizer audits hardest
    common = list(rng.integers(4, 60, size=17))
    prompts = [common + list(rng.integers(4, 60, size=n))
               for n in (3, 9, 0, 26)]

    ref, _ = run(base, decs, prompts, sanitize=False)
    got, eng = run(base, decs, prompts, sanitize=True)
    if got != ref:
        print("FAIL: sanitize=True diverged from sanitize=False", ref, got)
        return 1
    assert eng.sanitizer.checks > 0
    print(f"sanitize smoke OK: {eng.sanitizer.checks} step boundaries "
          f"checked, {sum(len(t) for t in got)} tokens bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
