"""Training loops: base pretraining, Full-FT, and cache-conditioned FT,
plus greedy evaluation with shared / self / mixed caches (Fig. 2 machinery).
"""
from __future__ import annotations

import functools
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefillshare import (base_prefill, cache_conditioned_loss,
                                     full_ft_loss, mix_caches)
from repro.models import forward
from repro.training import data as D
from repro.training.optim import AdamW, apply_updates


class Trainer:
    """jit-compiled generic (loss, AdamW) loop over keyword batches."""

    def __init__(self, loss_fn: Callable, opt: AdamW):
        self.opt = opt

        @jax.jit
        def step(params, opt_state, batch):
            def lf(p):
                out = loss_fn(p, **batch)
                return out[0] if isinstance(out, tuple) else out
            loss, grads = jax.value_and_grad(lf)(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss

        self._step = step

    def fit(self, params, batches: Iterable[dict], log_every: int = 0,
            tag: str = ""):
        opt_state = self.opt.init(params)
        losses = []
        for i, batch in enumerate(batches):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, loss = self._step(params, opt_state, batch)
            losses.append(float(loss))
            if log_every and (i + 1) % log_every == 0:
                print(f"[{tag}] step {i+1}: loss {np.mean(losses[-log_every:]):.4f}")
        return params, losses


# ----------------------------------------------------------------------
# Convenience wiring for the synthetic domains


def pretrain_batches(cfg: ModelConfig, seed: int, steps: int, batch: int,
                     spec: D.TaskSpec | None = None):
    """Plain LM batches over the task mixture (the 'foundation' corpus)."""
    spec = spec or D.TaskSpec(domain="mix", vocab=cfg.vocab_size)
    for b in D.batches(seed, spec, batch, steps):
        tokens = np.concatenate([b.prompt, b.target_in], 1)
        tgt = np.concatenate([b.prompt[:, 1:], b.target_in[:, :1], b.target_out], 1)
        mask = np.concatenate([(b.prompt != D.PAD).astype(np.float32)[:, 1:],
                               np.ones((b.prompt.shape[0], 1), np.float32),
                               b.target_mask], 1)
        yield {"tokens": tokens, "targets": tgt, "mask": mask}


def finetune_full(cfg: ModelConfig, params, domain: str, *, seed: int,
                  steps: int, batch: int, lr: float = 1e-3, log_every: int = 0,
                  spec: D.TaskSpec | None = None):
    spec = spec or D.TaskSpec(domain=domain, vocab=cfg.vocab_size)
    loss_fn = functools.partial(full_ft_loss, cfg)
    tr = Trainer(loss_fn, AdamW(lr, weight_decay=0.01))
    feed = ({"prompt": b.prompt, "target_in": b.target_in,
             "target_out": b.target_out, "target_mask": b.target_mask}
            for b in D.batches(seed, spec, batch, steps))
    return tr.fit(params, feed, log_every=log_every, tag=f"full-ft/{domain}")


def finetune_cache_conditioned(cfg: ModelConfig, dec_params, base_params,
                               domain: str, *, seed: int, steps: int, batch: int,
                               lr: float = 1e-3, share_ratio: float = 1.0,
                               log_every: int = 0,
                               spec: D.TaskSpec | None = None):
    spec = spec or D.TaskSpec(domain=domain, vocab=cfg.vocab_size)

    def loss_fn(p, **kw):
        return cache_conditioned_loss(cfg, p, base_params,
                                      share_ratio=share_ratio, **kw)

    tr = Trainer(loss_fn, AdamW(lr, weight_decay=0.01))
    feed = ({"prompt": b.prompt, "target_in": b.target_in,
             "target_out": b.target_out, "target_mask": b.target_mask}
            for b in D.batches(seed, spec, batch, steps))
    return tr.fit(dec_params, feed, log_every=log_every,
                  tag=f"cachecond/{domain}")


# ----------------------------------------------------------------------
# Evaluation: greedy decode conditioned on a (possibly foreign) prompt cache


@functools.partial(jax.jit, static_argnums=(0, 5))
def _greedy(cfg: ModelConfig, dec_params, cache, pos, first_token, n_steps):

    def body(carry, _):
        cache, pos, tok = carry
        logits, cache, _ = forward(cfg, dec_params, tok[:, None], cache=cache,
                                   pos=pos)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return (cache, pos + 1, nxt), nxt

    (_, _, _), toks = jax.lax.scan(body, (cache, pos, first_token),
                                   None, length=n_steps)
    return jnp.moveaxis(toks, 0, 1)  # (B, n_steps)


def evaluate(cfg: ModelConfig, dec_params, base_params, domain: str, *,
             seed: int, batches: int = 4, batch: int = 64,
             share_ratio: float = 1.0, spec: D.TaskSpec | None = None,
             per_token: bool = False) -> float:
    """Exact-match accuracy decoding from a prompt cache that is
    share_ratio-mixed between the base model's (shared) and the decode
    model's own prefill. ratio=1 -> PrefillShare serving; ratio=0 -> classic
    per-model serving."""
    spec = spec or D.TaskSpec(domain=domain, vocab=cfg.vocab_size)
    accs = []
    for b in D.batches(seed + 1000, spec, batch, batches):
        Bn, Sp = b.prompt.shape
        St = b.target_out.shape[1]
        cache_len = Sp + St + 1
        prompt = jnp.asarray(b.prompt)
        _, c_base = base_prefill(cfg, base_params, prompt, cache_len=cache_len)
        if share_ratio < 1.0:
            _, c_self = base_prefill(cfg, dec_params, prompt, cache_len=cache_len)
            cache = mix_caches(cfg, c_base, c_self, share_ratio)
        else:
            cache = c_base
        pos = jnp.full((Bn,), Sp, jnp.int32)
        first = jnp.full((Bn,), D.SEP, jnp.int32)
        pred = _greedy(cfg, dec_params, cache, pos, first, St)
        if per_token:
            ok = ((np.asarray(pred) == b.target_out) * b.target_mask).sum()
            accs.append(ok / b.target_mask.sum())
        else:
            accs.append(D.answer_accuracy(np.asarray(pred), b.target_out,
                                          b.target_mask))
    return float(np.mean(accs))
