from repro.training.optim import AdamW, apply_updates, constant_lr, warmup_cosine
