"""Synthetic task-domain data pipeline.

Stands in for the paper's MetaMathQA / EvolInstruct-Code / xLAM domains with
three prompt-dependent tasks a tiny transformer can learn on CPU. Every answer
is a pure function of the PROMPT, so solving the task requires actually
reading the prompt's cache — which is exactly what cache-conditioned
fine-tuning must preserve when the cache comes from a frozen base model.

Domains (our Table-1 analogues):
  math    — cumulative sum mod 10 of a digit sequence ("GSM8K")
  copy    — forward copy of the payload ("HumanEval": exact structured output)
  reverse — reverse copy (harder positional variant, used in --full runs)
  lookup  — key/value recall: answer the value of the queried keys ("BFCL")

Token map: 0=PAD 1=BOS 2=SEP 3=EOS; payload symbols start at 4.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

PAD, BOS, SEP, EOS = 0, 1, 2, 3
SYM0 = 4          # payload symbols: SYM0 .. SYM0+n_symbols-1

DOMAINS = ("math", "copy", "reverse", "lookup", "mix")


@dataclass
class TaskSpec:
    domain: str
    prompt_len: int = 24      # payload tokens in prompt
    n_symbols: int = 10
    vocab: int = 64


def _gen_one(rng: np.random.Generator, spec: TaskSpec):
    n = spec.prompt_len
    s0 = SYM0
    if spec.domain == "mix":
        spec = TaskSpec(domain=str(rng.choice(["math", "copy", "lookup"])),
                        prompt_len=spec.prompt_len, n_symbols=spec.n_symbols,
                        vocab=spec.vocab)
    if spec.domain == "math":
        digits = rng.integers(0, spec.n_symbols, n)
        ans = np.cumsum(digits) % spec.n_symbols
        prompt = digits + s0
        answer = ans + s0
    elif spec.domain == "copy":
        # forward copy: induction-head-learnable in O(100) steps at tiny scale
        payload = rng.integers(0, spec.n_symbols, n)
        prompt = payload + s0
        answer = payload.copy() + s0
    elif spec.domain == "reverse":
        payload = rng.integers(0, spec.n_symbols, n)
        prompt = payload + s0
        answer = payload[::-1] + s0
    elif spec.domain == "lookup":
        k = min(n // 2, spec.n_symbols)
        keys = rng.permutation(spec.n_symbols)[:k]
        vals = rng.integers(0, spec.n_symbols, k)
        pairs = np.stack([keys, vals], 1).reshape(-1)  # k1 v1 k2 v2 ...
        qi = rng.permutation(k)
        prompt = np.concatenate([pairs, keys[qi]]) + s0
        answer = vals[qi] + s0
    else:
        raise ValueError(spec.domain)
    return prompt.astype(np.int32), answer.astype(np.int32)


@dataclass
class Batch:
    prompt: np.ndarray       # (B, Sp) BOS + payload + SEP
    target_in: np.ndarray    # (B, St) teacher-forced decoder input
    target_out: np.ndarray   # (B, St) next-token labels
    target_mask: np.ndarray  # (B, St)


def make_batch(rng: np.random.Generator, spec: TaskSpec, batch: int) -> Batch:
    ps, ans = zip(*[_gen_one(rng, spec) for _ in range(batch)])
    sp = max(len(p) for p in ps) + 2
    st = max(len(a) for a in ans) + 1
    P = np.zeros((batch, sp), np.int32)
    TI = np.zeros((batch, st), np.int32)
    TO = np.zeros((batch, st), np.int32)
    M = np.zeros((batch, st), np.float32)
    for i, (p, a) in enumerate(zip(ps, ans)):
        row = np.concatenate([[BOS], p, [SEP]])
        P[i, -len(row):] = row              # left-pad (keeps SEP adjacent to target)
        ti = np.concatenate([[SEP], a])[: st]
        to = np.concatenate([a, [EOS]])[: st]
        TI[i, : len(ti)] = ti
        TO[i, : len(to)] = to
        M[i, : len(to)] = 1.0
    # NOTE: with uniform prompt_len, all rows have identical lengths; padding
    # logic is exercised by property tests with ragged specs.
    return Batch(P, TI, TO, M)


def batches(seed: int, spec: TaskSpec, batch: int, steps: int) -> Iterator[Batch]:
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        yield make_batch(rng, spec, batch)


def answer_accuracy(pred_tokens: np.ndarray, target_out: np.ndarray,
                    target_mask: np.ndarray) -> float:
    """Exact-match over masked answer positions (EOS included)."""
    ok = (pred_tokens == target_out) | (target_mask == 0)
    return float(ok.all(axis=-1).mean())
