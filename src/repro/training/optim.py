"""From-scratch optimizers: AdamW (paper Appendix A settings) + LR schedules."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: any
    v: any


def warmup_cosine(base_lr: float, total_steps: int, warmup_ratio: float = 0.03,
                  final_frac: float = 0.1) -> Callable:
    warm = max(1, int(total_steps * warmup_ratio))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        w = base_lr * step / warm
        t = jnp.clip((step - warm) / jnp.maximum(total_steps - warm, 1), 0.0, 1.0)
        c = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warm, w, c)

    return lr


def constant_lr(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


class AdamW:
    """AdamW (Loshchilov & Hutter 2017). β1=0.9, β2=0.999, wd=0.1 per the paper.

    Moment dtype is configurable: the big-config train dry-run uses bf16
    moments to fit grok-1 optimizer state on a v5e pod (see EXPERIMENTS.md)."""

    def __init__(self, lr_fn, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.1,
                 moment_dtype=jnp.float32, grad_clip: float = 0.0):
        self.lr_fn = lr_fn if callable(lr_fn) else constant_lr(lr_fn)
        self.b1, self.b2, self.eps, self.wd = b1, b2, eps, weight_decay
        self.moment_dtype = moment_dtype
        self.grad_clip = grad_clip

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=self.moment_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(z, params), v=jax.tree.map(z, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: (b1 * mm.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32)
                                        ).astype(self.moment_dtype),
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: (b2 * vv.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(g.astype(jnp.float32))
                                        ).astype(self.moment_dtype),
                         state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr_fn(step)

        def upd(p, mm, vv):
            mh = mm.astype(jnp.float32) / bc1
            vh = vv.astype(jnp.float32) / bc2
            u = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + self.wd * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, AdamWState(step=step, m=m, v=v)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
