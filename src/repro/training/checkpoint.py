"""Checkpointing: pytree <-> .npz with a JSON treedef manifest."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":        # npz can't serialize ml_dtypes
            arr = arr.astype(np.float32)
        keyed[key] = arr
    return keyed, treedef


def save(path: str, tree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    keyed, _ = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **keyed)
    manifest = {"keys": sorted(keyed), "meta": meta or {}}
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(manifest, f)


def load(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    keyed, treedef = _flatten(like)
    leaves = []
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    for pathk, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        arr = npz[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        import jax.numpy as jnp
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)
