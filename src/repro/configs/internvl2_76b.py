"""internvl2-76b [vlm] — InternViT + LLaMA3-70B-class language backbone.

[arXiv:2404.16821]. The InternViT-6B vision encoder + MLP projector are STUBBED
per the assignment carve-out: ``input_specs`` supplies precomputed patch
embeddings prepended to token embeddings; we implement the 80-layer language
decoder that consumes them.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    input_mode="mixed", n_prefix_embeds=256,   # 256 visual patch tokens
    rope_theta=500000.0,
    source="arXiv:2404.16821",
))
