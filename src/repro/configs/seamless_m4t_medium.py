"""seamless-m4t-medium [audio] — encoder-decoder multimodal backbone.

[arXiv:2308.11596]. The speech frontend (mel + conformer feature extractor) is
STUBBED per the assignment carve-out: ``input_specs`` supplies precomputed frame
embeddings consumed by the text/unit encoder; we implement the enc-dec
transformer backbone (12 encoder + 12 decoder layers).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12, encoder_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    input_mode="embeds",
    source="arXiv:2308.11596",
))
