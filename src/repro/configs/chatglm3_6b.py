"""chatglm3-6b [dense] — partial (2d) RoPE, extreme GQA (kv=2).

[arXiv:2406.12793]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    arch_type="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=65024,
    rope_style="partial",          # rotary on half the head dims (GLM 2d RoPE)
    source="arXiv:2406.12793",
))
