"""Model configuration system.

Every assigned architecture (plus the paper's own backbones) is expressed as a
``ModelConfig``. The same dataclass drives model construction, sharding policy,
dry-run input specs, and the serving cost model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Layer kinds usable in ``layer_pattern`` (the repeating block group).
ATTN = "attn"            # global full attention
LOCAL_ATTN = "local_attn"  # sliding-window attention
RGLRU = "rglru"          # RG-LRU recurrent block (Griffin / RecurrentGemma)
SSD = "ssd"              # Mamba-2 state-space duality block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int                   # decoder layers (pattern repeats to this depth)
    d_model: int
    n_heads: int                    # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- attention features ---
    rope_style: str = "full"        # full | partial (chatglm 2d/partial rotary) | none
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None    # gemma2 attention logit softcap
    final_softcap: Optional[float] = None   # gemma2 final logit softcap
    sliding_window: int = 0                 # window for local_attn layers
    layer_pattern: Tuple[str, ...] = (ATTN,)
    qk_norm: bool = False                   # qwen3-style per-head q/k RMSNorm

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0

    # --- SSM / recurrent ---
    ssm_state: int = 0              # mamba2 N (state size per head)
    ssm_head_dim: int = 64          # mamba2 P
    ssm_expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    rglru_width: int = 0            # RG-LRU recurrent width (0 -> d_model)

    # --- encoder-decoder ---
    encoder_layers: int = 0         # > 0 => enc-dec (decoder cross-attends)

    # --- modality frontend (stubbed per spec) ---
    input_mode: str = "tokens"      # tokens | embeds (audio frames / vision patches)
    n_prefix_embeds: int = 0        # VLM: patch embeds prepended to token embeds

    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                # citation for the config

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def uses_attention(self) -> bool:
        return any(k in (ATTN, LOCAL_ATTN) for k in self.layer_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no layer holds unbounded full-attention KV... except that we
        treat gemma2-style half-sliding-window as eligible for long-context
        decode (decode is O(L) per token; see DESIGN.md §4)."""
        return ATTN not in self.layer_pattern

    @property
    def long_context_ok(self) -> bool:
        """Eligible for the long_500k decode shape."""
        if self.subquadratic:
            return True
        # dense archs qualify only with a native sliding-window variant
        return LOCAL_ATTN in self.layer_pattern and self.sliding_window > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kinds of the full decoder stack."""
        pat = self.layer_pattern
        reps = -(-self.n_layers // len(pat))
        return (pat * reps)[: self.n_layers]

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND roofline."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        per_kind = {}
        per_kind[ATTN] = per_kind[LOCAL_ATTN] = (
            d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        )
        mlp = 3 * d * f  # gated MLP
        if self.is_moe:
            moe = self.n_experts * 3 * d * f + d * self.n_experts
            mlp = moe + self.n_shared_experts * 3 * d * f
        d_in = self.ssm_expand * d
        if self.ssm_state:
            nh = d_in // self.ssm_head_dim
            per_kind[SSD] = (
                d * (2 * d_in + 2 * self.ssm_state + nh)  # in_proj for x,z,B,C,dt
                + self.conv_width * (d_in + 2 * self.ssm_state)
                + d_in * d
                + 2 * nh
            )
        w = self.rglru_width or d
        per_kind[RGLRU] = d * w * 2 + 3 * w * w // 1 + w * d if RGLRU in self.layer_pattern else 0
        # NOTE: rglru block = in proj (d->w x2 gates), conv, rg-lru gates (w->w x2), out proj
        attn_like = 0
        for kind in self.layer_kinds():
            blk = per_kind.get(kind, 0)
            if kind in (ATTN, LOCAL_ATTN):
                blk += mlp
            elif kind == RGLRU:
                blk += mlp if self.d_ff else 0
            attn_like += blk + 2 * d  # norms
        total += attn_like
        if self.is_encdec:
            # encoder: self-attn + mlp; decoder blocks above get cross-attn added
            enc_block = per_kind[ATTN] + 3 * d * f + 2 * d
            total += self.encoder_layers * enc_block
            total += self.n_layers * (per_kind[ATTN] + d)  # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * d * f
        active_moe = (self.top_k + self.n_shared_experts) * 3 * d * f
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in (ATTN, LOCAL_ATTN))
        return self.param_count() - n_moe_layers * (dense_moe - active_moe)

    # ------------------------------------------------------------------
    def reduced(self, n_layers: int = 2, d_model: int = 256, vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests (<=4 experts etc.)."""
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, n_heads)) if n_heads else 0
        d_model = min(d_model, 512)
        updates = dict(
            name=self.name + "-smoke",
            n_layers=max(n_layers, len(self.layer_pattern)),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=(d_model // n_heads) if n_heads else 0,
            d_ff=d_model * 2 if self.d_ff else 0,
            vocab_size=vocab,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            rglru_width=d_model if self.rglru_width else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 8),
            dtype="float32",
        )
        return dataclasses.replace(self, **updates)


# ----------------------------------------------------------------------
# Input shapes (assigned)
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ----------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


ASSIGNED = [
    "granite-moe-3b-a800m", "gemma2-27b", "seamless-m4t-medium", "chatglm3-6b",
    "recurrentgemma-2b", "granite-8b", "internlm2-1.8b", "grok-1-314b",
    "internvl2-76b", "mamba2-780m",
]


def _load_all():
    import importlib
    mods = [
        "granite_moe_3b_a800m", "gemma2_27b", "seamless_m4t_medium", "chatglm3_6b",
        "recurrentgemma_2b", "granite_8b", "internlm2_1_8b", "grok_1_314b",
        "internvl2_76b", "mamba2_780m", "llama31_8b", "qwen3",
    ]
    for m in mods:
        importlib.import_module(f"repro.configs.{m}")
