"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118]
"""
from repro.configs.base import ModelConfig, register, LOCAL_ATTN, ATTN

CONFIG = register(ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    layer_pattern=(LOCAL_ATTN, ATTN),
    sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    rope_theta=10000.0,
    source="arXiv:2408.00118",
))
