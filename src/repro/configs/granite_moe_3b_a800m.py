"""granite-moe-3b-a800m [moe] — IBM Granite 3.0 3B-A800M MoE.

[hf:ibm-granite/granite-3.0-3b-a800m-base] (assignment bracket cites the
1b-a400m card with 32 experts; the assigned numbers — 32L/1536/24H/40e top-8 —
match the 3b-a800m card, which we follow; see DESIGN.md deviations).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512,                      # per-expert FFN width
    vocab_size=49155,
    n_experts=40, top_k=8,
    rope_theta=10000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (assigned); 3b-a800m dims",
))
