"""mamba2-780m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, register, SSD

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    layer_pattern=(SSD,),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    rope_style="none",
    source="arXiv:2405.21060",
))
