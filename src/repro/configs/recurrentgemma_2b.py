"""recurrentgemma-2b [hybrid] — Griffin: RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, register, RGLRU, LOCAL_ATTN

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    layer_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    sliding_window=2048,
    rglru_width=2560,
    source="arXiv:2402.19427",
))
