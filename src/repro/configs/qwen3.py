"""Qwen3-1.7B/8B/14B-Base — the paper's scale-sweep backbones.

[arXiv:2505.09388]
"""
from repro.configs.base import ModelConfig, register

QWEN3_1_7B = register(ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    source="arXiv:2505.09388",
))

QWEN3_8B = register(ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    source="arXiv:2505.09388 (paper's backbone)",
))

QWEN3_14B = register(ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    source="arXiv:2505.09388 (paper Appendix B.3 backbone)",
))
