"""grok-1-314b [moe] — 8 experts top-2.

[hf:xai-org/grok-1]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2,
    attn_softcap=30.0,             # grok uses attention logit softcapping
    source="hf:xai-org/grok-1",
))
