from repro.configs.base import (
    ModelConfig, InputShape, INPUT_SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K,
    LONG_500K, ASSIGNED, get_config, list_configs, register,
    ATTN, LOCAL_ATTN, RGLRU, SSD,
)
