"""LLaMA-3.1-8B — the paper's own serving/training backbone.

[arXiv:2407.21783]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama31-8b",
    arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=500000.0, tie_embeddings=False,
    source="arXiv:2407.21783 (paper's backbone)",
))
