from repro.core.prefillshare import (CacheSchema, base_prefill,
                                     cache_conditioned_loss, cache_schema,
                                     full_ft_loss, mix_caches,
                                     model_fingerprint)
