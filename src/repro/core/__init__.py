from repro.core.prefillshare import (CacheSchema, base_prefill,
                                     base_prefill_paged,
                                     cache_conditioned_loss, cache_schema,
                                     full_ft_loss, mix_caches,
                                     model_fingerprint)
