"""Beyond-paper: LoRA decode modules under cache-conditioned fine-tuning.

The paper fine-tunes FULL decode modules (N × full model storage on the
decode pool). A natural extension: keep the decode module = frozen base +
low-rank adapters, trained with the SAME cache-conditioned objective (Eq. 7).
If it holds accuracy, the decode pool stores ONE base copy + N tiny adapter
sets — compounding the paper's memory argument (Eq. 9) on the weight side the
way PrefillShare already compounds it on the KV side.

Adapter trees mirror the base param tree: every targeted weight position
holds a ``LoRAPair`` (a NamedTuple, hence a proper pytree node — gradients
and optimizers traverse it transparently), every other position holds None.
The pair is a DEDICATED type, not a bare ``{"A", "B"}`` dict: classification
happens by position (a base LEAF pairs with whatever subtree the adapter
tree holds there) and by ``isinstance``, so a real param subtree that merely
happens to have keys A/B can never be mistaken for an adapter
(tests/test_lora.py::test_real_param_subtree_named_a_b_is_not_an_adapter).

Serving has two ways to consume adapters:
  - ``lora_apply`` materializes ``W_eff = W + (alpha/r)·(A @ B)`` once (the
    legacy per-model decode path);
  - the fused decode plane stacks just the (tiny) A/B factors
    (``stack_lora_params``) and performs the same merge INSIDE its jitted
    vmapped step (serving/decode.py), so N adapter-factored decode modules
    store one base copy + N adapter sets instead of N full models.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

DEFAULT_TARGETS = ("wq", "wv", "wo")


class LoRAPair(NamedTuple):
    """One adapter: ``delta = scale * A @ B``. NamedTuple => pytree node."""
    A: Any
    B: Any


def _is_target(path, targets) -> bool:
    leafname = str(getattr(path[-1], "key", path[-1]))
    return leafname in targets


def lora_init(key, base_params, *, rank: int = 8,
              targets=DEFAULT_TARGETS) -> Any:
    """A/B pairs (A ~ N(0, 1/r), B = 0) for every targeted 2D+ weight."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(base_params)
    out = []
    for i, (path, leaf) in enumerate(flat):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and _is_target(path, targets):
            *batch, m, n = leaf.shape
            ka = jax.random.fold_in(key, i)
            a = jax.random.normal(ka, (*batch, m, rank), jnp.float32) / rank
            b = jnp.zeros((*batch, rank, n), jnp.float32)
            out.append(LoRAPair(a.astype(leaf.dtype), b.astype(leaf.dtype)))
        else:
            out.append(None)
    return jax.tree_util.tree_unflatten(treedef, out)


def _pair(ab):
    """View ``ab`` as an adapter pair, else None. LoRAPair is the canonical
    type; a bare two-key {"A", "B"} dict is still accepted here — at a base
    LEAF position it is unambiguous (the base tree was already flattened, so
    no base subtree can be swallowed by the check)."""
    if isinstance(ab, LoRAPair):
        return ab
    if isinstance(ab, dict) and set(ab) == {"A", "B"}:
        return LoRAPair(ab["A"], ab["B"])
    return None


def lora_delta(ab: LoRAPair, scale: float):
    """The low-rank update ``scale * A @ B`` in float32."""
    return jnp.einsum("...mr,...rn->...mn", ab.A.astype(jnp.float32),
                      ab.B.astype(jnp.float32)) * scale


def lora_apply(base_params, lora_params, *, alpha: float = 16.0,
               rank: int = 8):
    """Materialize effective params: W + (alpha/rank) * A @ B.

    Adapter classification is positional: the merge pairs each base LEAF
    with the adapter tree's subtree at the same position, and only a
    ``LoRAPair`` there is treated as an adapter (None and any real param
    structure pass through untouched). No ``is_leaf`` key-sniffing — the old
    ``set(x) == {"A", "B"}`` heuristic could misclassify a genuine base
    param subtree with those key names and crash (or silently corrupt) the
    merge."""
    scale = alpha / rank

    def merge(w, ab):
        pair = _pair(ab)
        if pair is None:
            return w
        return (w.astype(jnp.float32) + lora_delta(pair, scale)).astype(w.dtype)

    # flatten_up_to semantics: base leaves drive; the adapter tree's whole
    # subtree at each base-leaf position (LoRAPair or None) reaches merge.
    leaves, treedef = jax.tree_util.tree_flatten(base_params)
    ab_subtrees = treedef.flatten_up_to(lora_params)
    return jax.tree_util.tree_unflatten(
        treedef, [merge(w, ab) for w, ab in zip(leaves, ab_subtrees)])


def lora_param_count(lora_params) -> int:
    return sum(x.size for x in jax.tree.leaves(lora_params))


def stack_params(param_list):
    """Stack structurally-identical param pytrees on a NEW leading model axis.

    This is the fused decode plane's parameter layout: N task-specific decode
    modules sharing one ModelConfig become one pytree whose every leaf is
    (N, ...), so a single vmapped forward advances sequences of all N models
    in one dispatch (serving.decode.StackedDecoders)."""
    assert param_list, "need at least one param pytree to stack"
    return jax.tree.map(lambda *xs: jnp.stack(xs), *param_list)


def stack_lora_params(lora_list):
    """``stack_params`` for LoRA adapter pytrees (None where untargeted).

    Memory-lean variant of the fused plane for adapter-only decoders: stack
    just the (tiny) A/B factors and merge ``W + scale * A[m] @ B[m]`` inside
    the vmapped step, instead of stacking N full materialized models. None
    positions (untargeted weights) are empty pytree nodes and survive as-is;
    adapter-target mismatches between the stacked models surface as a tree
    structure error."""
    assert lora_list, "need at least one adapter pytree to stack"
    try:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *lora_list)
    except ValueError as e:
        raise ValueError(
            f"cannot stack adapters: targeted-weight sets differ across the "
            f"{len(lora_list)} models ({e})") from e


def cache_conditioned_lora_loss(cfg, lora_params, base_params, prompt,
                                target_in, target_out, target_mask, *,
                                alpha: float = 16.0, rank: int = 8,
                                share_ratio: float = 1.0, **kw):
    """Eq. 7 with θ_dec = θ_base + LoRA; gradients flow ONLY to the adapters
    (θ_base enters both the frozen prefill and the decode trunk, but is a
    constant w.r.t. the optimizer)."""
    from repro.core.prefillshare import cache_conditioned_loss
    dec = lora_apply(jax.lax.stop_gradient(base_params), lora_params,
                     alpha=alpha, rank=rank)
    return cache_conditioned_loss(cfg, dec, base_params, prompt, target_in,
                                  target_out, target_mask,
                                  share_ratio=share_ratio, **kw)
