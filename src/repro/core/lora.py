"""Beyond-paper: LoRA decode modules under cache-conditioned fine-tuning.

The paper fine-tunes FULL decode modules (N × full model storage on the
decode pool). A natural extension: keep the decode module = frozen base +
low-rank adapters, trained with the SAME cache-conditioned objective (Eq. 7).
If it holds accuracy, the decode pool stores ONE base copy + N tiny adapter
sets — compounding the paper's memory argument (Eq. 9) on the weight side the
way PrefillShare already compounds it on the KV side.

Implementation: adapters target the attention projections (wq, wv, wo) and
are materialized as ``W_eff = W + (alpha/r)·(A @ B)`` right before the decode
forward — at serving time this merge happens once per model swap, so decode
kernels are unchanged.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

DEFAULT_TARGETS = ("wq", "wv", "wo")


def _is_target(path, targets) -> bool:
    leafname = str(getattr(path[-1], "key", path[-1]))
    return leafname in targets


def lora_init(key, base_params, *, rank: int = 8,
              targets=DEFAULT_TARGETS) -> Any:
    """A/B pairs (A ~ N(0, 1/r), B = 0) for every targeted 2D+ weight."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(base_params)
    out = []
    for i, (path, leaf) in enumerate(flat):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and _is_target(path, targets):
            *batch, m, n = leaf.shape
            ka = jax.random.fold_in(key, i)
            a = jax.random.normal(ka, (*batch, m, rank), jnp.float32) / rank
            b = jnp.zeros((*batch, rank, n), jnp.float32)
            out.append({"A": a.astype(leaf.dtype), "B": b.astype(leaf.dtype)})
        else:
            out.append(None)
    return jax.tree_util.tree_unflatten(treedef, out)


def lora_apply(base_params, lora_params, *, alpha: float = 16.0,
               rank: int = 8):
    """Materialize effective params: W + (alpha/rank) * A @ B."""
    scale = alpha / rank

    def merge(w, ab):
        if ab is None:
            return w
        delta = jnp.einsum("...mr,...rn->...mn", ab["A"].astype(jnp.float32),
                           ab["B"].astype(jnp.float32)) * scale
        return (w.astype(jnp.float32) + delta).astype(w.dtype)

    return jax.tree.map(merge, base_params, lora_params,
                        is_leaf=lambda x: x is None or (
                            isinstance(x, dict) and set(x) == {"A", "B"}))


def lora_param_count(lora_params) -> int:
    return sum(x.size for x in jax.tree.leaves(lora_params))


def stack_params(param_list):
    """Stack structurally-identical param pytrees on a NEW leading model axis.

    This is the fused decode plane's parameter layout: N task-specific decode
    modules sharing one ModelConfig become one pytree whose every leaf is
    (N, ...), so a single vmapped forward advances sequences of all N models
    in one dispatch (serving.decode.StackedDecoders)."""
    assert param_list, "need at least one param pytree to stack"
    return jax.tree.map(lambda *xs: jnp.stack(xs), *param_list)


def stack_lora_params(lora_list):
    """``stack_params`` for LoRA adapter pytrees (None where untargeted).

    Memory-lean variant of the fused plane for adapter-only decoders: stack
    just the (tiny) A/B factors and merge ``W + scale * A[m] @ B[m]`` inside
    the vmapped step, instead of stacking N full materialized models."""
    assert lora_list, "need at least one adapter pytree to stack"

    def s(*xs):
        if xs[0] is None:
            assert all(x is None for x in xs), "adapter targets differ"
            return None
        return jnp.stack(xs)

    return jax.tree.map(s, *lora_list, is_leaf=lambda x: x is None)


def cache_conditioned_lora_loss(cfg, lora_params, base_params, prompt,
                                target_in, target_out, target_mask, *,
                                alpha: float = 16.0, rank: int = 8,
                                share_ratio: float = 1.0, **kw):
    """Eq. 7 with θ_dec = θ_base + LoRA; gradients flow ONLY to the adapters
    (θ_base enters both the frozen prefill and the decode trunk, but is a
    constant w.r.t. the optimizer)."""
    from repro.core.prefillshare import cache_conditioned_loss
    dec = lora_apply(jax.lax.stop_gradient(base_params), lora_params,
                     alpha=alpha, rank=rank)
    return cache_conditioned_loss(cfg, dec, base_params, prompt, target_in,
                                  target_out, target_mask,
                                  share_ratio=share_ratio, **kw)
