"""PrefillShare core (paper §3): factorization + cache-conditioned fine-tuning.

The model is factorized into
  - a *base prefill module* ``θ_base`` (frozen): processes the shared prompt X
    once, producing the shared sequence state ``C_base`` (KV cache for
    attention archs, SSD/RG-LRU state for SSM/hybrid archs — DESIGN.md §4);
  - N *task-specific decode modules* ``θ_dec``: generate conditioned on
    ``C_base``.

Cache-conditioned fine-tuning (Eq. 7):
    L(θ_dec) = −Σ_t log P(y_t | y_<t, stop_grad(C_base); θ_dec)
Teacher forcing over the target, with the prompt's cache produced by the
frozen base model. Because every decode module is trained against the *same*
frozen prefill parameterization, their caches are mutually compatible and the
prefill stage + cache can be shared across models at serving time.

``share_ratio`` implements the paper's Fig. 2 knob: the fraction of layers
whose prompt cache comes from the base model (the rest come from the decode
model's own prefill). ratio=1.0 is the PrefillShare operating point;
sweeping it against a normally-fine-tuned model reproduces the collapse curve.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encode, forward, init_cache
from repro.models.model import train_loss as _plain_train_loss

Params = Any
Cache = Any


# ======================================================================
# Base prefill module


def base_prefill(cfg: ModelConfig, base_params: Params, tokens, *, cache_len: int,
                 pos=None, cache: Optional[Cache] = None, prefix_embeds=None,
                 enc_embeds=None, stop_grad: bool = True, flash=None):
    """Run the (frozen) base prefill module; returns (last_logits, C_base).

    Supports PARTIAL prefill: pass an existing ``cache`` + ``pos`` to extend it
    with newly appended tokens only (paper §3.3 step 1).
    """
    B = tokens.shape[0]
    enc_out = None
    if cfg.is_encdec and enc_embeds is not None:
        enc_out = encode(cfg, base_params, enc_embeds, flash=flash)
    if cache is None:
        enc_len = enc_embeds.shape[1] if enc_embeds is not None else 0
        cache = init_cache(cfg, B, cache_len, enc_len=enc_len)
    if pos is None:
        pos = jnp.zeros((B,), jnp.int32)
    out, cache, _ = forward(cfg, base_params, tokens, cache=cache, pos=pos,
                            prefix_embeds=prefix_embeds, enc_out=enc_out,
                            flash=flash)
    if stop_grad:
        cache = jax.lax.stop_gradient(cache)
    return out, cache


def base_prefill_paged(cfg: ModelConfig, base_params: Params, new_tokens, *,
                       pool, block_table, n_cached: int, flash=None):
    """Partial prefill against the paged data plane (§3.3 step 1, for real).

    The cached prefix (``n_cached`` tokens, page-aligned by construction —
    the prefix index matches whole blocks) is gathered out of ``pool`` via
    ``block_table`` into a dense working cache; the frozen base model runs
    over ``new_tokens`` only; the freshly produced KV rows are scattered back
    into the pool's physical pages with the ``paged_write`` kernel. Returns
    the last-token logits. B=1 (one request per call).

    Mixed-provenance contract: the cached pages may be prefill-published OR
    relay-published (decode-written by a finished sequence whose KV path
    equals the base module's — ``engine._relay_compatible`` gates
    publication). Both hold position p's KV for the token INPUT at p, bit-
    identical to what this function would itself have written, so the
    gather treats them uniformly; no provenance plumbing reaches here.
    """
    assert n_cached % pool.page_size == 0, "prefix reuse is page-granular"
    cache = pool.gather_prefill_cache(block_table, n_cached)
    out, cache = base_prefill(
        cfg, base_params, new_tokens,
        cache_len=len(block_table) * pool.page_size, cache=cache,
        pos=jnp.array([n_cached], jnp.int32), flash=flash)
    start = n_cached // pool.page_size
    pool.scatter_from_dense(cache, block_table, start,
                            len(block_table) - start)
    return out


_CHUNK_STEPS: dict = {}
#: retrace counter per config (the trace-scaling tests read this): the jitted
#: chunk step retraces per distinct (B, S, npages) shape — with the
#: scheduler's power-of-two table bucketing, npages contributes O(log pages)
#: retraces instead of one per page of prefix growth.
CHUNK_TRACES: dict = {}


def _make_chunk_step(cfg: ModelConfig):
    def _step(params, toks, pos, cache):
        CHUNK_TRACES[cfg] = CHUNK_TRACES.get(cfg, 0) + 1   # once per trace
        _, new_cache, _ = forward(cfg, params, toks, cache=cache, pos=pos,
                                  logits="hidden")
        return new_cache
    return jax.jit(_step)


def base_prefill_chunk(cfg: ModelConfig, base_params: Params, tokens, *,
                       pool, block_tables, pos):
    """One chunked-prefill step against the paged plane (the scheduler's
    prefill primitive).

    Unlike ``base_prefill_paged`` there is NO dense gather of the prefix:
    inside one jitted forward, each layer scatters the chunk's fresh K/V
    rows into their pool pages and the chunk queries attend to prefix+self
    straight from the pages (``flash_prefill_paged`` on TPU, the jnp gather
    twin elsewhere). The prefix pages obey the same mixed-provenance
    contract as ``base_prefill_paged``: prefill-published and
    relay-published (decode-written) pages are indistinguishable here.
    Batches chunks from several requests: ``tokens`` (B, S) int32, ``pos``
    (B,) absolute start positions, ``block_tables`` (B, npages) zero-padded
    to a common width. Chunk start positions and
    the cached-prefix boundary may land mid-page. Returns the updated-page
    pytree (already absorbed into ``pool``) for completion sync.
    """
    if cfg not in _CHUNK_STEPS:
        _CHUNK_STEPS[cfg] = _make_chunk_step(cfg)
    step = _CHUNK_STEPS[cfg]
    cache = pool.make_decode_cache(jnp.asarray(block_tables, jnp.int32))
    new_cache = step(base_params, jnp.asarray(tokens, jnp.int32),
                     jnp.asarray(pos, jnp.int32), cache)
    pool.absorb_decode_cache(new_cache)
    return new_cache


# ======================================================================
# Share-ratio mixing (Fig. 2 mechanism)


def _layer_share_mask(cfg: ModelConfig, ratio: float):
    """Boolean per layer: True = use the base model's cache for this layer.

    The first ``round(ratio * n_layers)`` layers share (bottom-up, matching
    the paper's progressive-sharing sweep)."""
    n = cfg.n_layers
    k = int(round(ratio * n))
    return [i < k for i in range(n)]


def mix_caches(cfg: ModelConfig, cache_base: Cache, cache_self: Cache,
               ratio: float) -> Cache:
    """Per-layer blend: layers under the share mask take the base cache."""
    if ratio >= 1.0:
        return cache_base
    if ratio <= 0.0:
        return cache_self
    mask = _layer_share_mask(cfg, ratio)
    pat = cfg.layer_pattern
    n_full = cfg.n_layers // len(pat)

    def pick(path_mask_stacked, b, s):
        # b, s: stacked leaves (n_full, ...); path_mask_stacked: (n_full,) bools
        sel = jnp.asarray(path_mask_stacked)
        shape = (n_full,) + (1,) * (b.ndim - 1)
        return jnp.where(sel.reshape(shape), b, s)

    mixed_groups = {}
    for i in range(len(pat)):
        layer_ids = [g * len(pat) + i for g in range(n_full)]
        m = [mask[j] for j in layer_ids]
        bg = cache_base["groups"][f"pos{i}"]
        sg = cache_self["groups"][f"pos{i}"]
        mixed_groups[f"pos{i}"] = jax.tree.map(lambda b, s: pick(m, b, s), bg, sg)
    mixed_tail = []
    for t, (bt, st) in enumerate(zip(cache_base["tail"], cache_self["tail"])):
        lid = n_full * len(pat) + t
        mixed_tail.append(bt if mask[lid] else st)
    return {"groups": mixed_groups, "tail": mixed_tail}


# ======================================================================
# Cache-conditioned fine-tuning loss (Eq. 7)


def cache_conditioned_loss(cfg: ModelConfig, dec_params: Params,
                           base_params: Params, prompt, target_in, target_out,
                           target_mask, *, share_ratio: float = 1.0,
                           prefix_embeds=None, enc_embeds=None, remat: bool = False,
                           flash=None, ce_chunk: int = 512):
    """−Σ log P(y_t | y_<t, C_base; θ_dec), gradients only through θ_dec.

    prompt: (B, Sp) shared-context tokens; target_in/out: (B, St) teacher-forced
    decoder input and next-token labels; target_mask: (B, St).
    ``share_ratio < 1`` mixes in the decode model's own prompt cache (used to
    train/eval intermediate sharing points for Fig. 2).
    """
    B, Sp = prompt.shape
    St = target_in.shape[1]
    npfx = prefix_embeds.shape[1] if prefix_embeds is not None else 0
    cache_len = Sp + npfx + St

    _, c_base = base_prefill(cfg, base_params, prompt, cache_len=cache_len,
                             prefix_embeds=prefix_embeds, enc_embeds=enc_embeds,
                             stop_grad=True, flash=flash)
    if share_ratio < 1.0:
        _, c_self = base_prefill(cfg, dec_params, prompt, cache_len=cache_len,
                                 prefix_embeds=prefix_embeds,
                                 enc_embeds=enc_embeds, stop_grad=False,
                                 flash=flash)
        cache = mix_caches(cfg, c_base, c_self, share_ratio)
    else:
        cache = c_base

    pos = jnp.full((B,), Sp + npfx, jnp.int32)
    hidden, _, aux = forward(cfg, dec_params, target_in, cache=cache, pos=pos,
                             logits="hidden", flash=flash, remat=remat)

    table = dec_params.get("unembed", dec_params["embed"])
    from repro.models.layers import unembed
    logits = unembed(hidden, table, cfg.final_softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, target_out[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * target_mask
    loss = nll.sum() / jnp.maximum(target_mask.sum(), 1.0)
    if cfg.is_moe:
        loss = loss + 0.01 * aux["lb_loss"]
    return loss, aux


def full_ft_loss(cfg: ModelConfig, params: Params, prompt, target_in, target_out,
                 target_mask, **kw):
    """Baseline: standard full fine-tuning (self-generated cache implicitly).

    Implemented as a plain next-token loss over [prompt; target] with the loss
    masked to the target segment — the conventional setup the paper compares
    against."""
    tokens = jnp.concatenate([prompt, target_in], axis=1)
    pmask = jnp.zeros_like(prompt, dtype=jnp.float32)
    # next-token targets: shift left; prompt positions masked out except the
    # boundary token which predicts target_in[0] -> included via target side
    tgt = jnp.concatenate([prompt[:, 1:], target_in[:, :1], target_out], axis=1)
    mask = jnp.concatenate([pmask, target_mask], axis=1)
    return _plain_train_loss(cfg, params, tokens, tgt, mask, remat=False,
                             prefix_embeds=kw.get("prefix_embeds"),
                             enc_embeds=kw.get("enc_embeds"))


# ======================================================================
# Cache compatibility schema (handoff contract)


@dataclass(frozen=True)
class CacheSchema:
    """Identity of a shared cache: which frozen base produced it, over what."""
    base_model_id: str       # id of θ_base (hash of config + param fingerprint)
    arch: str
    n_layers: int
    cache_len: int
    dtype: str

    def compatible_with(self, other: "CacheSchema") -> bool:
        return (self.base_model_id == other.base_model_id
                and self.arch == other.arch
                and self.n_layers == other.n_layers
                and self.dtype == other.dtype)


def model_fingerprint(cfg: ModelConfig, params: Params) -> str:
    """Cheap, deterministic parameter fingerprint (sum/norm of a few leaves)."""
    leaves = jax.tree.leaves(params)
    probe = [float(jnp.sum(leaf).astype(jnp.float32)) for leaf in leaves[:4]]
    blob = json.dumps({"cfg": cfg.name, "n": len(leaves), "probe": probe})
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def cache_schema(cfg: ModelConfig, base_params: Params, cache_len: int) -> CacheSchema:
    return CacheSchema(
        base_model_id=model_fingerprint(cfg, base_params),
        arch=cfg.name, n_layers=cfg.n_layers, cache_len=cache_len,
        dtype=cfg.dtype)
