"""Pallas TPU kernel: paged decode attention over the shared KV pool.

TPU-native adaptation of vLLM's PagedAttention (DESIGN.md §3): the decode
worker's KV lives in a paged pool; each sequence owns a block table mapping
logical pages -> physical pages. PrefillShare hands off *base-model* pages to
every decode worker, so the pool layout is the cross-model-shared artifact.

The block table + sequence lengths ride in scalar-prefetch (SMEM) via
``PrefetchScalarGridSpec``, so the K/V BlockSpec index maps dereference the
page table while the previous page streams HBM->VMEM. Grid iterates
(batch, kv_head, page); the full GQA query group for a kv head is processed
together (q block (group, D)), amortizing each K/V page fetch across the
group — the same trick the prefill kernel uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG = -1e30


def _kernel(block_tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, softcap: float,
            page: int, npages: int, group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    live = j * page < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale    # (group, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (group, page), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == npages - 1)
    def _final():
        o_ref[0, :, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           softcap: float = 0.0, scale: float | None = None,
                           interpret: bool = False):
    """Single-token decode attention over a paged KV pool.

    q:            (B, Hq, D) current-step queries
    k_pages:      (P, page_size, Hkv, D) physical key pool
    v_pages:      (P, page_size, Hkv, D) physical value pool
    block_tables: (B, npages) int32 logical->physical page ids
    lengths:      (B,) int32 valid KV length per sequence
    returns       (B, Hq, D)
    """
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    npages = block_tables.shape[1]
    group = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale

    # (B, Hkv, group, D): query group per kv head
    qg = q.reshape(B, Hkv, group, D)

    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               page=page, npages=npages, group=group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npages),
        in_specs=[
            # q: whole group for (b, h)
            pl.BlockSpec((1, group, 1, D),
                         lambda b, h, j, bt, ln: (b, 0, h, 0)),
            # k/v page: physical page id from the prefetched block table
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, 1, D),
                               lambda b, h, j, bt, ln: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, group, Hkv, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, qg.transpose(0, 2, 1, 3), k_pages, v_pages)
    return out.transpose(0, 2, 1, 3).reshape(B, Hq, D)
