"""Pallas TPU kernel: scatter freshly-prefilled KV into the paged pool.

The bridge between PrefillShare's shared prefill stage and the paged decode
pool: after the base model prefills (or partially prefills) a prompt, the new
K/V rows for tokens [pos, pos+S) are written into the physical pages assigned
by the block table. Grid iterates (batch, page-span); the block table rides in
scalar prefetch so the OUTPUT BlockSpec's index map selects the physical page
while the previous page is still being written. The pool is updated in place
via input-output aliasing (no copy of the multi-GB pool).

Assumes page-aligned writes (pos % page_size == 0) — the engine always
extends caches at block granularity, padding partial tails (vLLM does the
same).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(tables_ref, nvalid_ref, new_k_ref, new_v_ref, kpool_ref,
            vpool_ref, kout_ref, vout_ref, *, page: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j < nvalid_ref[b])
    def _write():
        kout_ref[...] = new_k_ref[...]
        vout_ref[...] = new_v_ref[...]

    @pl.when(j >= nvalid_ref[b])
    def _keep():
        # page not owned by this request: preserve pool contents
        kout_ref[...] = kpool_ref[...]
        vout_ref[...] = vpool_ref[...]


def paged_write(new_k, new_v, k_pages, v_pages, block_tables, n_valid, *,
                interpret: bool = False):
    """Write per-request new KV rows into their assigned physical pages.

    new_k/new_v:  (B, S, Hkv, D) freshly computed KV (S = n_pages * page)
    k/v_pages:    (P, page, Hkv, D) physical pools (updated in place)
    block_tables: (B, npages) int32 physical page per logical page
    n_valid:      (B,) int32 number of valid pages per request
    returns updated (k_pages, v_pages)
    """
    B, S, Hkv, D = new_k.shape
    P, page = k_pages.shape[0], k_pages.shape[1]
    npages = S // page
    assert npages == block_tables.shape[1], (npages, block_tables.shape)

    kernel = functools.partial(_kernel, page=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, page, Hkv, D), lambda b, j, bt, nv: (b, j, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D), lambda b, j, bt, nv: (b, j, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt, nv: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt, nv: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt, nv: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt, nv: (bt[b, j], 0, 0, 0)),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                   jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)],
        input_output_aliases={4: 0, 5: 1},   # pools updated in place
        # grid points may alias pool revisions (bt is data-dependent): keep
        # the page axis sequential; requests are independent.
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, n_valid, new_k, new_v, k_pages, v_pages)
