"""Pallas TPU kernel: flash prefill attention straight over the paged pool.

The chunked-prefill data path: a chunk of S freshly-embedded tokens (absolute
positions ``start .. start+S``) attends to the WHOLE sequence so far — the
cached prefix AND the chunk itself — reading K/V directly from the physical
pool pages named by the sequence's block table. This removes the dense
gather that ``base_prefill_paged`` does before every prefill (O(prefix)
HBM traffic per call): the prefix never leaves the pool.

Contract: the chunk's own K/V rows have already been scattered into their
pages (the model layer writes them before attending, exactly like the decode
step), so every query finds at least its own key. Causality falls out of the
absolute positions: page j holds keys ``j*page .. (j+1)*page``, and a key is
visible iff ``kpos <= qpos``. Pages entirely beyond the chunk end are skipped
whole (the same block-skip trick as the dense flash kernel).

Grid: (batch, kv_head, page) — the block table and per-sequence start
positions ride in scalar prefetch, so the K/V BlockSpec dereferences the page
table while the previous page streams HBM->VMEM. The full GQA query group
for a kv head — all S chunk positions at once — is processed per page fetch,
amortizing each page read across ``group * S`` query rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG = -1e30


def _kernel(bt_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, softcap: float,
            page: int, npages: int, chunk: int, rows: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    start = start_ref[b]
    # whole-block skip: pages entirely past the chunk's last position hold
    # nothing any query may see (kpos > qpos for every row)
    live = j * page < start + chunk

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        # row r = g*chunk + i -> query at absolute position start + i
        r = jax.lax.broadcasted_iota(jnp.int32, (rows, page), 0)
        qpos = start + r % chunk
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1)
        mask = kpos <= qpos
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == npages - 1)
    def _final():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill_paged(q, k_pages, v_pages, block_tables, start, *,
                        softcap: float = 0.0, scale: float | None = None,
                        interpret: bool = False):
    """Chunk-prefill attention over a paged KV pool.

    q:            (B, S, Hq, D) chunk queries; q[b, i] sits at absolute
                  position ``start[b] + i``
    k_pages:      (P, page_size, Hkv, D) physical key pool
    v_pages:      (P, page_size, Hkv, D) physical value pool
    block_tables: (B, npages) int32 logical->physical page ids (rows may be
                  zero-padded past a sequence's last page — masked out)
    start:        (B,) int32 absolute position of each chunk's first token;
                  the chunk's own K/V rows must already be in their pages
    returns       (B, S, Hq, D)
    """
    B, S, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    npages = block_tables.shape[1]
    group = Hq // Hkv
    rows = group * S
    scale = D ** -0.5 if scale is None else scale

    # (B, Hkv, group*S, D): all of a kv head's query rows, chunk-major per
    # group member (row r = g*S + i)
    qg = (q.reshape(B, S, Hkv, group, D)
           .transpose(0, 2, 3, 1, 4).reshape(B, Hkv, rows, D))

    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               page=page, npages=npages, chunk=S, rows=rows)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, npages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, D),
                         lambda b, h, j, bt, st: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, bt, st: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, j, bt, st: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, D),
                               lambda b, h, j, bt, st: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, D), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, start.astype(jnp.int32), qg, k_pages, v_pages)
    return (out.reshape(B, Hkv, group, S, D)
               .transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D))
