"""jit'd public wrappers for the Pallas kernels.

On a real TPU runtime these dispatch to the Mosaic-compiled kernels; on CPU
(this container) ``interpret=True`` executes the kernel bodies in Python for
correctness validation, and the model stack's pure-JAX flash path
(repro.models.attention) is the XLA-lowerable twin used by the dry-run.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.flash_prefill_paged import flash_prefill_paged
from repro.kernels.paged_decode import paged_decode_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None, interpret: bool | None = None):
    """Model-layout wrapper: q (B,S,Hq,D), k/v (B,T,Hkv,D) -> (B,S,Hq,D)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    o = flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal, window=window,
                      softcap=softcap, scale=scale, interpret=interp)
    return o.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "interpret"))
def paged_prefill(q, k_pages, v_pages, block_tables, start, *,
                  softcap: float = 0.0, scale=None,
                  interpret: bool | None = None):
    """Chunk-prefill attention over the paged pool: q (B, S, Hq, D) at
    absolute positions ``start[b] + i`` attends to prefix + chunk straight
    from the pages (no dense gather of the prefix)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return flash_prefill_paged(q, k_pages, v_pages, block_tables, start,
                               softcap=softcap, scale=scale, interpret=interp)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    softcap: float = 0.0, scale=None,
                    interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                                  softcap=softcap, scale=scale,
                                  interpret=interp)
