"""Pallas TPU kernel: fused flash attention for the (shared) prefill stage.

The prefill stage is PrefillShare's hot spot — the whole point of the paper is
to run it ONCE per shared prompt — so it must hit the MXU roofline. Blocked
online-softmax flash attention with:
  - GQA (the kv-head index map folds the q→kv group mapping, so K/V blocks are
    fetched once per kv head, not per q head),
  - causal + sliding-window masking with whole-block skipping (fully-masked
    K blocks are never computed, halving causal FLOPs),
  - Gemma-2-style attention logit softcap,
  - fp32 accumulation in VMEM scratch, bf16/f32 I/O.

Layout: q (B, Hq, S, D), k/v (B, Hkv, T, D) — head-major so a (block, D) tile
is contiguous in HBM and lands VMEM-aligned (D is a multiple of 128 for all
assigned archs except head_dim=64 archs, where the MXU tile is still fine with
lane padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            seq_k: int, bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # whole-block skip: causal (K block entirely in the future) or window
    # (K block entirely before the window of every query in the Q block)
    live = k_start < seq_k
    if causal:
        live &= k_start <= q_start + bq - 1
    if window:
        live &= (k_start + bk - 1) > (q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float | None = None,
                  block_q: int = 512, block_k: int = 512,
                  interpret: bool = False):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D) -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    bk = min(block_k, T)
    while T % bk:
        bk //= 2
    nq, nk = S // bq, T // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        seq_k=T, bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
