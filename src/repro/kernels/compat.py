"""Version-compat shims over the Pallas TPU API surface.

The TPU compiler-params dataclass was renamed across JAX releases:
``pltpu.TPUCompilerParams`` (jax <= 0.4.x) became ``pltpu.CompilerParams``
(newer releases, as documented in the current Pallas guide). Every kernel in
this package goes through :func:`tpu_compiler_params` so the same source
compiles against either API.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# Newer JAX exposes CompilerParams; 0.4.x calls it TPUCompilerParams.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct TPU compiler params portably across JAX versions.

    Typical use: ``compiler_params=tpu_compiler_params(
    dimension_semantics=("parallel", "arbitrary"))``.
    """
    return _COMPILER_PARAMS_CLS(**kwargs)
