"""Pallas TPU kernels for the paper's compute hot-spots (validated in
interpret mode on CPU; see tests/test_kernels.py):
  flash_prefill  — the shared prefill stage's fused attention
  paged_decode   — decode attention over the shared paged KV pool
  paged_write    — prefill -> pool page scatter (the handoff data plane)
"""
from repro.kernels.ops import flash_attention, paged_attention
from repro.kernels.paged_write import paged_write
