"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def ref_flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                      softcap: float = 0.0, scale: float | None = None):
    """q: (B,Hq,S,D); k/v: (B,Hkv,T,D) -> (B,Hq,S,D). Full materialized softmax."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, g, S, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgsd,bhtd->bhgst", qg, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, D).astype(q.dtype)


def ref_paged_decode(q, k_pages, v_pages, block_tables, lengths, *,
                     softcap: float = 0.0, scale: float | None = None):
    """Gather pages into contiguous KV, then masked softmax attention."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    npages = block_tables.shape[1]
    g = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale

    k = k_pages[block_tables]            # (B, npages, page, Hkv, D)
    v = v_pages[block_tables]
    T = npages * page
    k = k.reshape(B, T, Hkv, D)
    v = v.reshape(B, T, Hkv, D)

    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(T)[None] < lengths[:, None]          # (B, T)
    s = jnp.where(mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def ref_paged_prefill(q, k_pages, v_pages, block_tables, start, *,
                      softcap: float = 0.0, scale: float | None = None):
    """Chunk-prefill attention over pages: gather the block table into
    contiguous KV, then a materialized causal softmax at absolute positions
    (q[b, i] sits at ``start[b] + i``). Mirrors ``_direct``'s op ordering so
    chunked and dense prefill agree token-for-token."""
    B, S, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    npages = block_tables.shape[1]
    g = Hq // Hkv
    T = npages * page
    scale = D ** -0.5 if scale is None else scale

    k = k_pages[block_tables].reshape(B, T, Hkv, D)
    v = v_pages[block_tables].reshape(B, T, Hkv, D)

    qg = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None]     # (B, S)
    kpos = jnp.arange(T, dtype=jnp.int32)                            # (T,)
    mask = kpos[None, None, None, None, :] <= qpos[:, None, None, :, None]
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)


def ref_paged_write(new_k, new_v, k_pages, v_pages, block_tables, n_valid):
    """Scatter new KV rows into assigned pages (numpy-style oracle)."""
    import numpy as np
    B, S, Hkv, D = new_k.shape
    page = k_pages.shape[1]
    npages = S // page
    ko = np.array(k_pages)
    vo = np.array(v_pages)
    nk = np.array(new_k).reshape(B, npages, page, Hkv, D)
    nv = np.array(new_v).reshape(B, npages, page, Hkv, D)
    bt = np.array(block_tables)
    for b in range(B):
        for j in range(int(n_valid[b])):
            ko[bt[b, j]] = nk[b, j]
            vo[bt[b, j]] = nv[b, j]
    return jnp.asarray(ko), jnp.asarray(vo)
