"""Serving-invariant static analyzer (stdlib-only; safe without jax).

Usage: ``python -m repro.analysis src tests --baseline
.analysis-baseline.json``. See docs/api.md "Static analysis & sanitizer"
for the rule catalog (RPR001-RPR006) and baselining workflow.
"""
from repro.analysis.baseline import (apply_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.core import (Finding, ModuleContext, Rule, analyze_paths,
                                 fingerprint_findings, iter_python_files,
                                 parse_module)
from repro.analysis.rules import ALL_RULES, RULES_BY_ID

__all__ = ["Finding", "ModuleContext", "Rule", "analyze_paths",
           "fingerprint_findings", "iter_python_files", "parse_module",
           "ALL_RULES", "RULES_BY_ID", "apply_baseline", "load_baseline",
           "save_baseline"]
