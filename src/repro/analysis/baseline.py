"""Checked-in baseline of accepted findings.

The baseline (``.analysis-baseline.json`` at the repo root) records findings
that are *intentional* — each entry carries the finding's fingerprint plus a
one-line justification. The CLI subtracts baselined findings from its output
and exits 0; anything new fails the run. Fingerprints hash the rule + path +
enclosing function + normalized source line (not line numbers), so the
baseline survives unrelated edits; if the offending line itself changes, the
entry goes stale and the finding resurfaces — which is the desired behavior,
since the justification was written for the old code.
"""
from __future__ import annotations

import json
import os

from repro.analysis.core import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = ".analysis-baseline.json"


def load_baseline(path: str) -> dict[str, dict]:
    """fingerprint -> entry dict. Missing file is an empty baseline."""
    if not os.path.isfile(path):
        return {}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if not isinstance(data, dict) or data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline format in {path!r} "
                         f"(want version={BASELINE_VERSION})")
    out = {}
    for entry in data.get("entries", []):
        out[entry["fingerprint"]] = entry
    return out


def save_baseline(path: str, findings: list[Finding],
                  notes: dict[str, str] | None = None) -> None:
    """Write ``findings`` as the new baseline. ``notes`` maps fingerprints
    to justifications; entries without one get a TODO marker so review
    catches them."""
    notes = notes or {}
    entries = [{
        "fingerprint": f.fingerprint,
        "rule": f.rule,
        "path": f.path,
        "func": f.func,
        "line_text": f.line_text,
        "note": notes.get(f.fingerprint, "TODO: justify this baseline entry"),
    } for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))]
    payload = {"version": BASELINE_VERSION, "entries": entries}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def apply_baseline(findings: list[Finding], baseline: dict[str, dict]):
    """Split findings into (new, accepted) and report stale baseline
    fingerprints that matched nothing this run."""
    new, accepted = [], []
    hit: set[str] = set()
    for f in findings:
        if f.fingerprint in baseline:
            accepted.append(f)
            hit.add(f.fingerprint)
        else:
            new.append(f)
    stale = [e for fp, e in baseline.items() if fp not in hit]
    return new, accepted, stale
