"""Rule catalog: serving invariants this repo depends on, as AST checks.

Every rule is grounded in a bug class the engine has already hit or is one
refactor away from hitting (see docs/api.md "Static analysis & sanitizer"
for the rationale catalog):

- RPR001 donation-after-use — a buffer handed into a donating jitted call
  (``donate_argnums``, or the ``decode_state``/``absorb_decode_state``
  donation-aware pairs) is read again before rebinding. On TPU the donated
  buffer is dead after the call; off-TPU the read silently works, so only
  static analysis (and the PoolSanitizer's poisoning) catches it.
- RPR002 refcount-balance — a function takes pool references
  (``alloc``/``ref``/``acquire``/``begin``/``extend``) and then performs
  fallible work with no ``unref``/``drop``/``release``/``abandon`` on any
  exception path: one raise and the pages leak as permanently-active.
  Relay-KV note: relay publication (``_finish``/``_relay_publish``) is a
  RELEASE-side discipline the AST rule cannot see — every page the tree
  adopts must be ``unref``'d to CACHED (never left ACTIVE, never ``drop``'d
  out from under the tree) in the same ``_finish``, and non-adopted private
  pages must still be hard-dropped. The runtime half enforces it: the
  PoolSanitizer's step census treats relay-published pages as first-class
  (an ACTIVE holderless relay page is diagnosed by name) and
  ``check_index`` rejects a tree that serves a FREE page.
- RPR003 host-sync-in-hot-path — ``block_until_ready``/``np.asarray``/
  ``.item()``/``float(x[i])`` inside scheduler/decode step loops serializes
  the device pipeline per step (or worse, per token).
- RPR004 unbucketed-shape-into-jit — a dynamic length-derived value reaches
  a jitted call's array shapes without the pow2 bucketing helper, so jit
  retraces grow with prompt/table length instead of O(log).
- RPR005 side-effect-in-jit — Python side effects (``self.x += 1``,
  ``print``, ``time.*``) inside a jit-traced function run once per TRACE,
  not per call: counters silently stop counting after the first step.
- RPR006 metrics-instrument-in-step — registry ``counter``/``gauge``/
  ``histogram`` get-or-create inside per-step code; instruments must be
  hoisted to ``__init__``/``_init_metrics`` so hot paths hold direct refs.
- RPR007 host-materialized-pool-pages — ``np.asarray``/``jax.device_get``
  on the paged pool's page buffers anywhere outside ``kvcache/swap.py``.
  The swap tier is the ONE sanctioned device->host path for pool KV (it is
  timed, fed to the preemption cost model, and censused by the sanitizer);
  an ad-hoc host copy elsewhere serializes the device pipeline against the
  whole pool and produces KV the swap census cannot account for.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import (Finding, ModuleContext, Rule, attr_chain,
                                 call_name, receiver_name, walk_calls)

# pool-ish receivers: method calls on these names are refcount operations
_POOLISH = re.compile(r"^(pool|mgr|manager|block_pool|blockpool)$")
ACQUIRE_METHODS = {"alloc", "ref", "acquire", "begin", "extend", "retain"}
# swap_out / discard_swapped are the swap tier's release-side transitions
# (device rows relinquished to the pool's SWAPPED/FREE populations): a
# rollback handler that re-parks reclaimed pages IS release discipline
RELEASE_METHODS = {"unref", "drop", "release", "abandon", "swap_out",
                   "discard_swapped"}

# calls that cannot plausibly raise between an acquire and its release
_SAFE_CALLS = {"append", "extend", "touch", "record_hit", "move_to_end",
               "setdefault", "get", "pop", "popitem", "items", "keys",
               "values", "add", "remove", "discard", "int", "len", "str",
               "float", "bool", "max", "min", "list", "tuple", "dict", "set",
               "sorted", "range", "hash", "isinstance", "copy", "enumerate",
               "zip"}

# names of the pow2 bucketing helpers that make a dynamic shape jit-safe
BUCKET_HELPERS = {"next_pow2", "pow2_bucket", "bucket_pow2"}

# jitted-call entry points by convention: the engine's jitted steps are
# stored/called as ``step``/``_step`` (DecodeWorker._step, StackedDecoders
# ._step, decoders[mid].step) — plus anything assigned from jax.jit(...)
_JIT_ENTRY_NAMES = {"step", "_step"}

# functions that ARE the per-step hot path (RPR003/RPR006 scope): decode and
# chunk-packing loops of the scheduler/engine/decode plane
HOT_FUNCS = {"step", "decode_step", "_decode_phase", "_batched_step",
             "_run_chunks", "_grow_tail_pages", "_promote", "_plan_chunks",
             "_reap_finished"}
_HOT_CLASS = re.compile(r"(Scheduler|Engine|Plane|Decoder|Worker)")


def _functions(ctx: ModuleContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _is_jax_jit(call: ast.Call) -> bool:
    chain = attr_chain(call.func)
    return chain[-2:] == ["jax", "jit"] or chain == ["jit"]


def _donated_positions(call: ast.Call, ctx: ModuleContext):
    """Parse ``donate_argnums=`` from a jax.jit call: a constant tuple, an
    IfExp over tuples (the repo's ``(0,) if tpu else ()`` idiom), or a Name
    bound to either nearby. Returns a set of positions, or None (no
    donation), or 'all' when unparseable (conservative)."""
    kw = next((k for k in call.keywords if k.arg == "donate_argnums"), None)
    if kw is None:
        return None

    def positions(node):
        if isinstance(node, ast.Tuple):
            out = set()
            for e in node.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    out.add(e.value)
            return out
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return {node.value}
        if isinstance(node, ast.IfExp):
            return positions(node.body) | positions(node.orelse)
        if isinstance(node, ast.Name):
            # resolve a simple local/module binding of the name
            fn = ctx.enclosing_function(call)
            scope = fn if fn is not None else ctx.tree
            for sub in ast.walk(scope):
                if (isinstance(sub, ast.Assign)
                        and len(sub.targets) == 1
                        and isinstance(sub.targets[0], ast.Name)
                        and sub.targets[0].id == node.id):
                    return positions(sub.value)
            return None
        return None

    got = positions(kw.value)
    return got if got is not None else "all"


def _jit_assignments(ctx: ModuleContext):
    """{last-name-of-target: donated-positions} for every
    ``X = jax.jit(...)`` in the module (donated-positions may be an empty
    set — still a jit entry for RPR004)."""
    out: dict = {}
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        if not (isinstance(node.value, ast.Call)
                and _is_jax_jit(node.value)):
            continue
        tgt = node.targets[0]
        chain = attr_chain(tgt)
        if not chain:
            continue
        donated = _donated_positions(node.value, ctx)
        out[chain[-1]] = donated if donated is not None else set()
    return out


def _ordered_nodes(fn, kind):
    out = [n for n in ast.walk(fn) if isinstance(n, kind)]
    out.sort(key=lambda n: (getattr(n, "lineno", 0),
                            getattr(n, "col_offset", 0)))
    return out


# ======================================================================
class DonationAfterUse(Rule):
    rule_id = "RPR001"
    title = "donation-after-use"

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        donators = {name: pos for name, pos in _jit_assignments(ctx).items()
                    if pos == "all" or pos}
        for fn in _functions(ctx):
            findings.extend(self._check_fn(ctx, fn, donators))
        return findings

    def _check_fn(self, ctx, fn, donators):
        # vars holding donation-aware pool state (decode_state /
        # make_decode_cache hand out buffers that a donating step consumes)
        handles: set[str] = set()
        donated: dict[str, ast.Call] = {}     # var -> donating call
        exempt: set[int] = set()              # Name node ids at donation site
        findings = []

        def key(n):
            # Assigns sort at their END so ``state = _step(state)`` processes
            # the donating call first, THEN the rebind clears it — reads
            # after a rebinding line must not flag
            if isinstance(n, ast.Assign):
                return (getattr(n, "end_lineno", n.lineno),
                        getattr(n, "end_col_offset", n.col_offset), 1)
            return (getattr(n, "lineno", 0), getattr(n, "col_offset", 0), 0)

        events = sorted(
            (n for n in ast.walk(fn)
             if isinstance(n, (ast.Call, ast.Name, ast.Assign))),
            key=key)
        for node in events:
            if isinstance(node, ast.Assign):
                # rebinding clears donation/handle state for the target
                for tgt in node.targets:
                    for t in ast.walk(tgt):
                        if isinstance(t, ast.Name):
                            donated.pop(t.id, None)
                            handles.discard(t.id)
                if (isinstance(node.value, ast.Call)
                        and call_name(node.value) in ("decode_state",
                                                      "make_decode_cache")
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    handles.add(node.targets[0].id)
                continue
            if isinstance(node, ast.Call):
                name = call_name(node)
                pos = donators.get(name)
                if pos is not None:
                    for i, a in enumerate(node.args):
                        if pos != "all" and i not in pos:
                            continue
                        if isinstance(a, ast.Name):
                            donated[a.id] = node
                            exempt.add(id(a))
                elif name in _JIT_ENTRY_NAMES:
                    # handing a pool-state handle into a jitted step donates
                    # it on TPU (the decode_state/absorb pair contract)
                    for a in node.args:
                        if isinstance(a, ast.Name) and a.id in handles:
                            donated[a.id] = node
                            exempt.add(id(a))
                continue
            # Name loads: a read of a donated var after the donating call
            if (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in donated
                    and id(node) not in exempt):
                site = donated[node.id]
                if (node.lineno, node.col_offset) > (site.lineno,
                                                     site.col_offset):
                    findings.append(self.finding(
                        ctx, node,
                        f"'{node.id}' was donated into "
                        f"'{call_name(site)}(...)' on line {site.lineno} and "
                        f"is read again before rebinding — after a donated "
                        f"jitted step the buffer is dead on TPU "
                        f"(decode_state/absorb_decode_state contract)"))
                    del donated[node.id]       # one finding per donation
        return findings


# ======================================================================
class RefcountBalance(Rule):
    rule_id = "RPR002"
    title = "refcount-balance"
    applies_to_tests = False        # tests corrupt pools on purpose

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for fn in _functions(ctx):
            acquires = []
            has_release_handler = False
            for node in ast.walk(fn):
                if isinstance(node, ast.Try):
                    guarded = list(node.finalbody)
                    for h in node.handlers:
                        guarded.extend(h.body)
                    for g in guarded:
                        for c in walk_calls(g):
                            if (call_name(c) in RELEASE_METHODS
                                    and (_POOLISH.match(receiver_name(c))
                                         or receiver_name(c) == "self")):
                                has_release_handler = True
            for c in walk_calls(fn):
                if (call_name(c) in ACQUIRE_METHODS
                        and _POOLISH.match(receiver_name(c))):
                    acquires.append(c)
            if not acquires or has_release_handler:
                continue
            first = min(acquires,
                        key=lambda c: (c.lineno, c.col_offset))
            risky = [
                c for c in walk_calls(fn)
                if (c.lineno, c.col_offset) > (first.lineno, first.col_offset)
                and call_name(c) not in _SAFE_CALLS
                and not (call_name(c) in ACQUIRE_METHODS
                         and _POOLISH.match(receiver_name(c)))
                and not (call_name(c) in RELEASE_METHODS
                         and _POOLISH.match(receiver_name(c)))]
            if risky:
                findings.append(self.finding(
                    ctx, first,
                    f"'{receiver_name(first)}.{call_name(first)}(...)' takes "
                    f"pool references but the enclosing function performs "
                    f"fallible work afterwards (e.g. "
                    f"'{call_name(risky[0])}(...)' on line "
                    f"{risky[0].lineno}) with no unref/drop/release/abandon "
                    f"on any exception path — a raise leaks the pages as "
                    f"permanently active"))
        return findings


# ======================================================================
class HostSyncInHotPath(Rule):
    rule_id = "RPR003"
    title = "host-sync-in-hot-path"
    applies_to_tests = False

    def _is_hot(self, ctx, fn) -> bool:
        if fn.name not in HOT_FUNCS:
            return False
        cls = ctx.enclosing_class(fn)
        if cls is not None and _HOT_CLASS.search(cls.name):
            return True
        return "serving/" in ctx.path

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for fn in _functions(ctx):
            if not self._is_hot(ctx, fn):
                continue
            for c in walk_calls(fn):
                name = call_name(c)
                recv = receiver_name(c)
                if name == "block_until_ready":
                    findings.append(self.finding(
                        ctx, c, "jax.block_until_ready in a per-step hot "
                        "path serializes the device pipeline every step"))
                elif name == "item" and not c.args and not c.keywords:
                    findings.append(self.finding(
                        ctx, c, ".item() on a device value in a per-step "
                        "hot path forces a device->host sync"))
                elif name == "asarray" and recv in ("np", "numpy", "onp"):
                    findings.append(self.finding(
                        ctx, c, "np.asarray in a per-step hot path copies "
                        "device memory to host synchronously"))
                elif (name in ("float", "int") and len(c.args) == 1
                        and isinstance(c.args[0], ast.Subscript)):
                    findings.append(self.finding(
                        ctx, c, f"{name}() on an indexed (device) value in "
                        f"a per-step hot path forces one device->host sync "
                        f"per element"))
        return findings


# ======================================================================
class UnbucketedShapeIntoJit(Rule):
    rule_id = "RPR004"
    title = "unbucketed-shape-into-jit"

    @staticmethod
    def _dynamic_len(expr) -> bool:
        """Expression derives a length from runtime data: contains a
        ``len(x)`` where x is not rooted at self (self attrs are stable
        across steps), or a ``.shape`` access."""
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call) and call_name(sub) == "len" \
                    and sub.args:
                chain = attr_chain(sub.args[0])
                if chain and chain[0] == "self":
                    continue
                return True
            if isinstance(sub, ast.Attribute) and sub.attr == "shape":
                return True
        return False

    @staticmethod
    def _bucketed(expr) -> bool:
        return any(isinstance(sub, ast.Call)
                   and call_name(sub) in BUCKET_HELPERS
                   for sub in ast.walk(expr))

    def check(self, ctx: ModuleContext) -> list[Finding]:
        jit_names = set(_jit_assignments(ctx)) | _JIT_ENTRY_NAMES
        findings = []
        for fn in _functions(ctx):
            entry_calls = [c for c in walk_calls(fn)
                           if call_name(c) in jit_names]
            if not entry_calls:
                continue
            shape_vars: dict[str, ast.Assign] = {}
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and self._dynamic_len(node.value)
                        and not self._bucketed(node.value)):
                    shape_vars[node.targets[0].id] = node
            if not shape_vars:
                continue
            flagged: set[str] = set()
            for c in walk_calls(fn):
                is_ctor = call_name(c) in ("zeros", "full", "empty", "ones")
                is_entry = call_name(c) in jit_names
                if not (is_ctor or is_entry):
                    continue
                for a in list(c.args) + [k.value for k in c.keywords]:
                    for sub in ast.walk(a):
                        if (isinstance(sub, ast.Name)
                                and isinstance(sub.ctx, ast.Load)
                                and sub.id in shape_vars
                                and sub.id not in flagged):
                            flagged.add(sub.id)
                            site = shape_vars[sub.id]
                            findings.append(self.finding(
                                ctx, site,
                                f"'{sub.id}' is a runtime length that "
                                f"reaches a jitted call's array shapes "
                                f"without pow2 bucketing (next_pow2) — jit "
                                f"retraces will grow with the data instead "
                                f"of O(log)"))
        return findings


# ======================================================================
class SideEffectInJit(Rule):
    rule_id = "RPR005"
    title = "side-effect-in-jit"

    _IMPURE_ROOTS = {"time", "random"}

    def _jit_target_defs(self, ctx: ModuleContext):
        """FunctionDefs that are jit-traced: passed by name to jax.jit, or
        decorated with jax.jit / partial(jax.jit, ...)."""
        jitted_names: set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and _is_jax_jit(node) and node.args:
                a0 = node.args[0]
                if isinstance(a0, ast.Name):
                    jitted_names.add(a0.id)
        targets = []
        for fn in _functions(ctx):
            if fn.name in jitted_names:
                targets.append(fn)
                continue
            for dec in fn.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                chain = attr_chain(d)
                if chain[-2:] == ["jax", "jit"] or chain == ["jit"]:
                    targets.append(fn)
                    break
                if isinstance(dec, ast.Call) and chain[-1:] == ["partial"]:
                    if any(attr_chain(a)[-2:] == ["jax", "jit"]
                           for a in dec.args):
                        targets.append(fn)
                        break
        # nested defs inside a traced function are traced too
        out = []
        seen = set()
        for fn in targets:
            for sub in ast.walk(fn):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and id(sub) not in seen:
                    seen.add(id(sub))
                    out.append(sub)
        return out

    @staticmethod
    def _walk_own(fn):
        """Walk fn's body, pruning nested defs — each nested def is its own
        entry in the target list, so its body is visited exactly once."""
        stack = list(ast.iter_child_nodes(fn))
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.extend(ast.iter_child_nodes(node))

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for fn in self._jit_target_defs(ctx):
            for node in self._walk_own(fn):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    tgts = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in tgts:
                        chain = attr_chain(t)
                        if len(chain) >= 2 and chain[0] == "self":
                            findings.append(self.finding(
                                ctx, node,
                                f"assignment to '{'.'.join(chain)}' inside "
                                f"a jit-traced function runs once per "
                                f"TRACE, not per call — hoist the side "
                                f"effect out of the traced body"))
                elif isinstance(node, (ast.Global, ast.Nonlocal)):
                    findings.append(self.finding(
                        ctx, node, "global/nonlocal mutation inside a "
                        "jit-traced function runs once per trace"))
                elif isinstance(node, ast.Call):
                    name = call_name(node)
                    chain = attr_chain(node.func)
                    if name == "print":
                        findings.append(self.finding(
                            ctx, node, "print inside a jit-traced function "
                            "fires once per trace (use jax.debug.print)"))
                    elif chain and chain[0] in self._IMPURE_ROOTS:
                        findings.append(self.finding(
                            ctx, node,
                            f"'{'.'.join(chain)}(...)' inside a jit-traced "
                            f"function is evaluated at trace time only"))
        return findings


# ======================================================================
class MetricsInstrumentInStep(Rule):
    rule_id = "RPR006"
    title = "metrics-instrument-in-step"
    applies_to_tests = False

    _ALLOWED_FUNCS = {"__init__", "_init_metrics", "__post_init__"}
    _RECEIVER = re.compile(r"(^reg$|registry$)")

    def check(self, ctx: ModuleContext) -> list[Finding]:
        findings = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and call_name(node) in ("counter", "gauge", "histogram")
                    and self._RECEIVER.search(receiver_name(node))):
                continue
            fn = ctx.enclosing_function(node)
            if fn is None or fn.name in self._ALLOWED_FUNCS:
                continue
            findings.append(self.finding(
                ctx, node,
                f"registry.{call_name(node)}(...) get-or-create inside "
                f"'{fn.name}' — instruments must be hoisted to __init__/"
                f"_init_metrics so per-step code holds direct references"))
        return findings


# ======================================================================
class HostMaterializedPoolPages(Rule):
    rule_id = "RPR007"
    title = "host-materialized-pool-pages"
    applies_to_tests = False        # tests assert on host copies on purpose

    #: the one sanctioned device->host path for pool page KV
    _SANCTIONED = "kvcache/swap.py"
    #: names that identify an expression as pool page state: the pool's
    #: buffer attributes, the pool object itself, and the whole-pool
    #: pytree accessor the swap tier gathers from
    _POOL_TOKENS = {"k_groups", "v_groups", "k_tail", "v_tail",
                    "kvpool", "kv_pool", "pool_state"}

    def check(self, ctx: ModuleContext) -> list[Finding]:
        if ctx.path.replace("\\", "/").endswith(self._SANCTIONED):
            return []
        findings = []
        for c in walk_calls(ctx.tree):
            name = call_name(c)
            recv = receiver_name(c)
            if not ((name == "asarray" and recv in ("np", "numpy", "onp"))
                    or name == "device_get"):
                continue
            toks: set[str] = set()
            for a in list(c.args) + [k.value for k in c.keywords]:
                for sub in ast.walk(a):
                    if isinstance(sub, ast.Name):
                        toks.add(sub.id)
                    elif isinstance(sub, ast.Attribute):
                        toks.add(sub.attr)
            if toks & self._POOL_TOKENS:
                findings.append(self.finding(
                    ctx, c,
                    f"'{name}(...)' materializes pool page buffers on the "
                    f"host outside {self._SANCTIONED} — the swap tier is "
                    f"the one sanctioned device->host path for pool KV "
                    f"(timed for the preemption cost model, censused by the "
                    f"sanitizer); an ad-hoc host copy serializes the device "
                    f"pipeline and escapes the swap census"))
        return findings


ALL_RULES = [DonationAfterUse(), RefcountBalance(), HostSyncInHotPath(),
             UnbucketedShapeIntoJit(), SideEffectInJit(),
             MetricsInstrumentInStep(), HostMaterializedPoolPages()]

RULES_BY_ID = {r.rule_id: r for r in ALL_RULES}
