"""``python -m repro.analysis <paths>`` — run the serving-invariant rules.

Exit codes: 0 clean (modulo baseline), 1 new findings, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.baseline import (DEFAULT_BASELINE, apply_baseline,
                                     load_baseline, save_baseline)
from repro.analysis.core import analyze_paths
from repro.analysis.rules import ALL_RULES, RULES_BY_ID


def _summary(findings) -> dict:
    by_rule: dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return dict(sorted(by_rule.items()))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Serving-invariant static analyzer for this repo "
                    "(rules RPR001-RPR006; see docs/api.md).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--root", default=".",
                    help="repo root paths are resolved against (default: .)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help=f"baseline JSON of accepted findings "
                         f"(default: <root>/{DEFAULT_BASELINE} if present)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to accept all current "
                         "findings (preserves existing notes)")
    ap.add_argument("--json", default=None, metavar="FILE", dest="json_out",
                    help="also write findings as JSON ('-' for stdout)")
    ap.add_argument("--rules", default=None, metavar="IDS",
                    help="comma-separated rule ids to run (default: all)")
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:
        return 0 if e.code in (0, None) else 2

    root = os.path.abspath(args.root)
    rules = ALL_RULES
    if args.rules:
        ids = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
        unknown = [i for i in ids if i not in RULES_BY_ID]
        if unknown:
            print(f"error: unknown rule id(s): {', '.join(unknown)} "
                  f"(have: {', '.join(RULES_BY_ID)})", file=sys.stderr)
            return 2
        rules = [RULES_BY_ID[i] for i in ids]

    for p in args.paths:
        ap_path = p if os.path.isabs(p) else os.path.join(root, p)
        if not os.path.exists(ap_path):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    findings = analyze_paths(args.paths, root, rules)

    baseline_path = args.baseline
    if baseline_path is None:
        candidate = os.path.join(root, DEFAULT_BASELINE)
        baseline_path = candidate if os.path.isfile(candidate) else None
    elif not os.path.isabs(baseline_path):
        baseline_path = os.path.join(root, baseline_path)

    if args.update_baseline:
        target = baseline_path or os.path.join(root, DEFAULT_BASELINE)
        notes = {}
        if os.path.isfile(target):
            try:
                notes = {fp: e.get("note", "")
                         for fp, e in load_baseline(target).items()}
            except ValueError:
                pass
        save_baseline(target, findings, notes)
        print(f"baseline updated: {len(findings)} finding(s) accepted in "
              f"{os.path.relpath(target, root)}")
        return 0

    baseline = {}
    if baseline_path:
        try:
            baseline = load_baseline(baseline_path)
        except (ValueError, json.JSONDecodeError, KeyError) as e:
            print(f"error: bad baseline {baseline_path}: {e}",
                  file=sys.stderr)
            return 2
    new, accepted, stale = apply_baseline(findings, baseline)

    if args.json_out:
        payload = {
            "version": 1,
            "findings": [dict(f.as_dict(),
                              baselined=f.fingerprint in baseline)
                         for f in findings],
            "summary": {
                "total": len(findings), "new": len(new),
                "baselined": len(accepted), "stale_baseline": len(stale),
                "by_rule": _summary(findings),
            },
        }
        text = json.dumps(payload, indent=2)
        if args.json_out == "-":
            print(text)
        else:
            out = args.json_out if os.path.isabs(args.json_out) \
                else os.path.join(root, args.json_out)
            with open(out, "w", encoding="utf-8") as f:
                f.write(text + "\n")

    for f in new:
        print(f.render())
    for e in stale:
        print(f"warning: stale baseline entry {e['fingerprint']} "
              f"({e['rule']} {e['path']}) matched nothing — remove it or "
              f"re-run with --update-baseline", file=sys.stderr)
    if new:
        print(f"\n{len(new)} new finding(s) "
              f"({len(accepted)} baselined, {len(stale)} stale baseline "
              f"entr{'y' if len(stale) == 1 else 'ies'}).")
        return 1
    if findings:
        extra = f", {len(stale)} stale" if stale else ""
        print(f"clean: 0 new findings ({len(accepted)} baselined{extra}).")
    else:
        print("clean: 0 findings.")
    return 0
