"""Analyzer core: findings, AST scanning scaffolding, fingerprints.

``repro.analysis`` is a repo-specific static analyzer: every rule encodes a
serving invariant this codebase actually depends on (donation discipline,
refcount balance, jit hygiene — see rules.py for the catalog and the bug
class each rule is grounded in). The core is deliberately stdlib-only: the
analyzer must run in CI images and pre-commit hooks that have no jax.

A ``Finding`` is anchored by a *fingerprint* — a hash of
(rule, path, enclosing function, normalized source line, occurrence index) —
NOT by its line number, so accepted findings in the checked-in baseline
survive unrelated edits that shift lines (see baseline.py).
"""
from __future__ import annotations

import ast
import hashlib
import os
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    rule: str                 # "RPR00x"
    path: str                 # repo-relative, forward slashes
    line: int
    col: int
    message: str
    func: str = "<module>"    # enclosing function qualname
    line_text: str = ""       # stripped source of the offending line
    fingerprint: str = ""     # stable id (assigned by fingerprint_findings)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}  [{self.fingerprint}]")

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message, "func": self.func,
                "line_text": self.line_text, "fingerprint": self.fingerprint}


@dataclass
class ModuleContext:
    """One parsed file, shared by every rule visiting it."""
    path: str                       # repo-relative
    tree: ast.Module
    source_lines: list[str]
    is_test: bool = False
    parents: dict = field(default_factory=dict)   # node -> parent node

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.source_lines):
            return self.source_lines[lineno - 1].strip()
        return ""

    def qualname(self, node: ast.AST) -> str:
        """Dotted enclosing-scope name for ``node`` (Class.method or
        function, '<module>' at top level)."""
        names = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                names.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(names)) or "<module>"

    def enclosing_class(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_function(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None


class Rule:
    """Base rule: subclasses set ``rule_id``/``title`` and implement
    ``check(ctx) -> list[Finding]`` (fingerprints are filled in later).
    ``applies_to_tests=False`` rules skip test files — their invariants
    target production paths (tests deliberately corrupt pools, sync devices
    mid-loop, etc.)."""

    rule_id = "RPR000"
    title = ""
    applies_to_tests = True

    def check(self, ctx: ModuleContext) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(rule=self.rule_id, path=ctx.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       message=message, func=ctx.qualname(node),
                       line_text=ctx.line_text(getattr(node, "lineno", 0)))


# ----------------------------------------------------------------------
# AST helpers shared by the rules
# ----------------------------------------------------------------------

def attr_chain(node: ast.AST) -> list[str]:
    """['self', 'pool', 'alloc'] for ``self.pool.alloc``; [] if the
    expression is not a plain name/attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def call_name(call: ast.Call) -> str:
    """Last component of the called name ('alloc' for self.pool.alloc(..))."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def receiver_name(call: ast.Call) -> str:
    """Name the method receiver: 'pool' for ``self.pool.alloc(...)``,
    '' for bare calls."""
    f = call.func
    if isinstance(f, ast.Attribute):
        chain = attr_chain(f)
        if len(chain) >= 2:
            return chain[-2]
    return ""


def walk_calls(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub


def is_test_path(path: str) -> bool:
    parts = path.replace(os.sep, "/").split("/")
    base = parts[-1]
    return ("tests" in parts[:-1] or base.startswith("test_")
            or base == "conftest.py")


def build_parents(tree: ast.Module) -> dict:
    parents: dict = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


# ----------------------------------------------------------------------
# scanning
# ----------------------------------------------------------------------

def iter_python_files(paths, root: str):
    """Yield repo-relative .py paths under ``paths`` (files or dirs),
    skipping caches/hidden dirs, sorted for deterministic output."""
    seen = set()
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            seen.add(os.path.relpath(ap, root))
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = sorted(d for d in dirnames
                                 if not d.startswith(".")
                                 and d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    seen.add(os.path.relpath(os.path.join(dirpath, fn), root))
    return sorted(s.replace(os.sep, "/") for s in seen)


def parse_module(relpath: str, root: str) -> ModuleContext | None:
    ap = os.path.join(root, relpath)
    try:
        with open(ap, encoding="utf-8") as f:
            src = f.read()
        tree = ast.parse(src, filename=relpath)
    except (OSError, SyntaxError):
        return None                      # unparseable: not this tool's beat
    ctx = ModuleContext(path=relpath, tree=tree,
                        source_lines=src.splitlines(),
                        is_test=is_test_path(relpath))
    ctx.parents = build_parents(tree)
    return ctx


def fingerprint_findings(findings: list[Finding]) -> list[Finding]:
    """Assign stable fingerprints: hash of (rule, path, func, normalized
    line text, occurrence index) — line numbers deliberately excluded so
    unrelated edits don't churn the baseline."""
    counts: dict[tuple, int] = {}
    out = []
    for f in findings:
        key = (f.rule, f.path, f.func, " ".join(f.line_text.split()))
        idx = counts.get(key, 0)
        counts[key] = idx + 1
        raw = "|".join((f.rule, f.path, f.func,
                        " ".join(f.line_text.split()), str(idx)))
        fp = hashlib.sha1(raw.encode()).hexdigest()[:12]
        out.append(Finding(rule=f.rule, path=f.path, line=f.line, col=f.col,
                           message=f.message, func=f.func,
                           line_text=f.line_text, fingerprint=fp))
    return out


def analyze_paths(paths, root: str, rules) -> list[Finding]:
    """Run every rule over every python file under ``paths``; returns
    fingerprinted findings sorted by (path, line, rule)."""
    findings: list[Finding] = []
    for relpath in iter_python_files(paths, root):
        ctx = parse_module(relpath, root)
        if ctx is None:
            continue
        for rule in rules:
            if ctx.is_test and not rule.applies_to_tests:
                continue
            findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.col))
    return fingerprint_findings(findings)
