"""Analytic FLOP / HBM-byte model per (arch × input shape).

Why analytic: ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified in EXPERIMENTS.md §Dry-run), so a scan-over-layers model under-
reports by ~n_layers. We control the model math exactly, so the roofline's
compute and memory terms come from this module; the collective term comes
from the loop-aware HLO parse (launch/hloanalysis.py); per-chip memory
footprint comes from ``memory_analysis()`` (which IS loop-safe).
``cost_analysis`` is retained in the dry-run records as a cross-check of the
per-body magnitude.

Conventions:
  MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (inference) —
  the "useful" figure the instructions define. flops/bytes below include
  attention/SSD terms, the CE/unembed matmul, remat recompute, and optimizer
  traffic, so MODEL_FLOPS / flops shows the structural overhead honestly.
"""
from __future__ import annotations

from repro.configs.base import (ATTN, LOCAL_ATTN, SSD, INPUT_SHAPES,
                                ModelConfig)
from repro.kvcache.manager import kv_bytes_per_token, state_bytes_per_seq

SSD_CHUNK = 64
FLASH_QCHUNK = 1024


def _attn_flops(cfg: ModelConfig, n_q: int, kv_len: int, batch: int,
                causal: bool) -> float:
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == ATTN:
            t = kv_len
        elif kind == LOCAL_ATTN:
            t = min(kv_len, cfg.sliding_window or kv_len)
        else:
            continue
        f = 4.0 * batch * n_q * t * cfg.n_heads * cfg.head_dim
        if causal and n_q == kv_len and kind == ATTN:
            f *= 0.5
        total += f
    if cfg.is_encdec:
        # decoder cross-attention over enc_len (= kv_len here) + encoder self
        total += 4.0 * batch * n_q * kv_len * cfg.n_heads * cfg.head_dim * cfg.n_layers / max(
            len(cfg.layer_kinds()), 1)
    return total


def _ssd_flops(cfg: ModelConfig, n_tokens: int, batch: int) -> float:
    if SSD not in cfg.layer_pattern:
        return 0.0
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    P, N, Q = cfg.ssm_head_dim, cfg.ssm_state, SSD_CHUNK
    n_ssd = sum(1 for k in cfg.layer_kinds() if k == SSD)
    per_tok = (2 * Q * N                 # intra-chunk scores C·B^T
               + 2 * Q * nh * P / max(nh, 1) * nh  # y_diag (Q per token)
               + 4 * nh * P * N)         # state update + y_off
    return float(n_ssd * batch * n_tokens * per_tok)


def _unembed_flops(cfg: ModelConfig, n_tokens: int, batch: int) -> float:
    return 2.0 * batch * n_tokens * cfg.vocab_size * cfg.d_model


def step_analytic(cfg: ModelConfig, shape_name: str) -> dict:
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    db = 2  # bf16
    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    npfx = cfg.n_prefix_embeds if cfg.input_mode == "mixed" else 0
    dec_len = S // 2 if cfg.is_encdec else S - npfx
    enc_len = S // 2 if cfg.is_encdec else 0
    kvt = kv_bytes_per_token(cfg, db)
    sps = state_bytes_per_seq(cfg)
    L, D = cfg.n_layers, cfg.d_model

    if shp.kind == "train":
        toks = dec_len + enc_len + npfx
        fwd = (2.0 * n_act * toks * B + _attn_flops(cfg, dec_len, dec_len, B, True)
               + _ssd_flops(cfg, dec_len, B) + _unembed_flops(cfg, dec_len, B))
        flops = 4.0 * fwd                       # fwd + bwd(2x) + remat re-fwd
        model_flops = 6.0 * n_act * toks * B
        bytes_ = (n_tot * db * 3                # weights: fwd, bwd, update
                  + n_tot * 2 * 2 * 2           # bf16 moments read+write x2
                  + n_tot * db * 2              # grads w + params rw
                  + 4.0 * L * B * dec_len * D * db)  # checkpointed activations
    elif shp.kind == "prefill":
        toks = dec_len + enc_len + npfx
        fwd_q = dec_len + npfx
        flops = (2.0 * n_act * toks * B
                 + _attn_flops(cfg, fwd_q, fwd_q, B, True)
                 + _ssd_flops(cfg, fwd_q, B)
                 + _unembed_flops(cfg, 1, B))
        model_flops = 2.0 * n_act * toks * B
        n_attn = sum(1 for k in cfg.layer_kinds() if k in (ATTN, LOCAL_ATTN))
        flash_reads = (fwd_q / FLASH_QCHUNK) * fwd_q * (
            kvt / max(n_attn, 1)) * n_attn * B if n_attn else 0
        bytes_ = (n_tot * db + B * toks * kvt + B * sps
                  + 2.0 * L * B * fwd_q * D * db + flash_reads)
    else:  # decode
        kv_len = S // 2 if cfg.is_encdec else S
        flops = (2.0 * n_act * B
                 + _attn_flops(cfg, 1, kv_len, B, False)
                 + _ssd_flops(cfg, 1, B)
                 + _unembed_flops(cfg, 1, B))
        model_flops = 2.0 * n_act * B
        eff_kv = 0
        for kind in cfg.layer_kinds():
            if kind == ATTN:
                eff_kv += kv_len
            elif kind == LOCAL_ATTN:
                eff_kv += min(kv_len, cfg.sliding_window or kv_len)
        per_layer_kv = kvt / max(
            sum(1 for k in cfg.layer_kinds() if k in (ATTN, LOCAL_ATTN)), 1)
        bytes_ = (n_tot * db + B * eff_kv * per_layer_kv + B * sps
                  + 2.0 * L * B * D * db)
    return {"flops": float(flops), "hbm_bytes": float(bytes_),
            "model_flops": float(model_flops)}
