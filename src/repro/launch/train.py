"""Training launcher: cache-conditioned fine-tuning end-to-end with
checkpointing.

CPU-runnable at reduced scale; the same step function lowers onto the
production mesh via dryrun.py. Example:

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --domain math --steps 200 --out /tmp/ps_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config
from repro.models import init_params
from repro.training import data as D
from repro.training.checkpoint import save
from repro.training.trainer import (evaluate, finetune_cache_conditioned,
                                    pretrain_batches, Trainer)
from repro.training.optim import AdamW, warmup_cosine
from repro.models.model import train_loss
import functools


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--domain", default="copy", choices=list(D.DOMAINS))
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--lr", type=float, default=1.5e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab=64)
    spec = D.TaskSpec(domain=args.domain, n_symbols=8, prompt_len=10,
                      vocab=cfg.vocab_size)
    print(f"[train] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"params~{cfg.param_count() / 1e6:.1f}M domain={args.domain}")

    t0 = time.time()
    base = init_params(cfg, jax.random.PRNGKey(args.seed))
    tr = Trainer(functools.partial(train_loss, cfg, remat=False),
                 AdamW(warmup_cosine(2e-3, args.pretrain_steps),
                       weight_decay=0.01))
    base, _ = tr.fit(base, pretrain_batches(
        cfg, args.seed, args.pretrain_steps, args.batch,
        spec=D.TaskSpec(domain="mix", n_symbols=8, prompt_len=10,
                        vocab=cfg.vocab_size)),
        log_every=100, tag="pretrain-base")
    save(f"{args.out}_base", base, meta={"arch": cfg.name, "role": "base"})

    dec, _ = finetune_cache_conditioned(
        cfg, base, base, args.domain, seed=args.seed + 1, steps=args.steps,
        batch=args.batch, lr=args.lr, spec=spec, log_every=100)
    save(f"{args.out}_{args.domain}", dec,
         meta={"arch": cfg.name, "role": f"decoder/{args.domain}"})

    acc = evaluate(cfg, dec, base, args.domain, seed=99, share_ratio=1.0,
                   spec=spec, per_token=True)
    print(f"[train] done in {time.time() - t0:.0f}s; shared-cache accuracy "
          f"{acc:.3f}; checkpoints at {args.out}_*")


if __name__ == "__main__":
    main()
