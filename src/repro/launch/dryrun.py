import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

_DOC = """Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) combo.

For each combination this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the step function + ShapeDtypeStruct inputs (no allocation),
  3. jit(...).lower(...).compile()  — proving the sharding config is coherent,
  4. records memory_analysis / cost_analysis / HLO collective bytes to JSONL
     (consumed by benchmarks/roofline.py and EXPERIMENTS.md).

Resumable: combos already in the output file are skipped.

Usage:
  python -m repro.launch.dryrun                       # all combos, single-pod
  python -m repro.launch.dryrun --multi-pod           # all combos, 2 pods
  python -m repro.launch.dryrun --arch gemma2-27b --shape decode_32k
"""


import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ASSIGNED, INPUT_SHAPES, get_config
from repro.launch.hloanalysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build

SKIP_LONG = "long_500k requires sub-quadratic attention (DESIGN.md §4)"


def combos(archs, shapes):
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            if s == "long_500k" and not cfg.long_context_ok:
                yield a, s, SKIP_LONG
            else:
                yield a, s, None


def run_one(arch: str, shape: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "chips": mesh.devices.size}
    t0 = time.time()
    with mesh:
        b = build(cfg, shape, mesh)
        jitted = jax.jit(b["fn"], in_shardings=b["in_shardings"],
                         donate_argnums=b.get("donate", ()))
        lowered = jitted.lower(*b["args"])
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k, 0) or 0) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")}
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or k == "optimal_seconds")}
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["hlo_len"] = len(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if "error" not in r:
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    n_ok = n_skip = n_fail = 0
    with open(args.out, "a") as f:
        for arch, shape, skip in combos(archs, shapes):
            key = (arch, shape, mesh_tag)
            if key in done:
                print(f"[dryrun] {arch} x {shape} x {mesh_tag}: cached")
                n_ok += 1
                continue
            if skip:
                print(f"[dryrun] {arch} x {shape}: SKIP ({skip})")
                f.write(json.dumps({"arch": arch, "shape": shape,
                                    "mesh": mesh_tag, "skipped": skip}) + "\n")
                f.flush()
                n_skip += 1
                continue
            print(f"[dryrun] {arch} x {shape} x {mesh_tag} ...", flush=True)
            try:
                rec = run_one(arch, shape, args.multi_pod)
                n_ok += 1
                per_chip = rec["memory"]["argument_size_in_bytes"]  # already per-chip
                print(f"  ok: lower {rec['lower_s']}s compile {rec['compile_s']}s "
                      f"args/chip {per_chip/1e9:.2f}GB "
                      f"flops {rec['cost'].get('flops', 0):.3g} "
                      f"coll {rec['collectives']['total']/1e9:.2f}GB",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                n_fail += 1
                print(f"  FAIL: {rec['error']}", flush=True)
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
