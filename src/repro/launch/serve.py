"""Serving launcher: run the disaggregated simulator (production cost terms)
or the real-JAX local engine, from the CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch llama31-8b \
      --pattern react --rate 4 --mode prefillshare
  PYTHONPATH=src python -m repro.launch.serve --engine local --gen 8
"""
from __future__ import annotations

import argparse
import json


def run_sim(args):
    from repro.configs.base import get_config
    from repro.serving.simulator import ServingConfig, Simulator
    from repro.serving.workload import make_sessions

    cfg = get_config(args.arch)
    sessions = make_sessions(args.pattern, n_sessions=args.sessions,
                             arrival_rate=args.rate, seed=args.seed)
    scfg = ServingConfig(mode=args.mode, max_concurrent=args.max_concurrent,
                         chips_per_worker=args.chips,
                         hbm_per_worker=args.chips * 16e9)
    sim = Simulator(cfg, scfg, sessions)
    print(json.dumps(sim.run(), indent=1))


def run_engine(args):
    import jax
    import numpy as np
    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.serving.api import SamplingParams
    from repro.serving.engine import LocalDisaggEngine

    cfg = ModelConfig(name="local", arch_type="dense", n_layers=3,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=64, dtype="float32")
    base = init_params(cfg, jax.random.PRNGKey(0))
    decs = {f"agent{i}": init_params(cfg, jax.random.PRNGKey(3 + i))
            for i in range(args.agents)}
    eng = LocalDisaggEngine(cfg, base, capacity=512)
    for mid, p in decs.items():
        eng.models.register(mid, p)
    rng = np.random.default_rng(0)
    ctx = list(rng.integers(4, 60, size=32))
    for turn in range(args.turns):
        for a in decs:
            ctx += list(rng.integers(4, 60, size=8))
            out = eng.generate(a, ctx, SamplingParams(max_tokens=args.gen),
                               session=0).result()
            ctx += list(out)
            print(f"turn {turn} {a}: ctx={len(ctx)} gen={out.tolist()}")
    s = eng.stats
    print(f"hit_ratio={s.hit_ratio:.3f} handoff_mb={s.handoff_bytes / 1e6:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=["sim", "local"], default="sim")
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--pattern", default="react")
    ap.add_argument("--mode", default="prefillshare",
                    choices=["baseline", "prefillshare"])
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--sessions", type=int, default=80)
    ap.add_argument("--max-concurrent", type=int, default=64)
    ap.add_argument("--chips", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--agents", type=int, default=3)
    ap.add_argument("--turns", type=int, default=2)
    ap.add_argument("--gen", type=int, default=6)
    args = ap.parse_args()
    (run_engine if args.engine == "local" else run_sim)(args)


if __name__ == "__main__":
    main()
