"""Parse compiled HLO text for collective traffic (roofline term 3).

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a scan of length 10 reports exactly the body's FLOPs), so any
naive sum over a scan-over-layers model undercounts by the layer count. This
parser is loop-aware: it builds the computation call graph (ENTRY -> while
bodies -> nested bodies), extracts each while's ``known_trip_count``, and
multiplies every collective's bytes by the product of trip counts on its call
path.

Byte semantics (post-SPMD HLO has *per-device* shapes, so totals are
per-chip link traffic):
  all-gather         : result bytes (already includes the group factor)
  all-reduce         : 2 x bytes (ring reduce-scatter + all-gather)
  reduce-scatter     : result bytes x group size (input volume)
  all-to-all         : result bytes
  collective-permute : result bytes
Async pairs: only ``-start`` ops are counted (max single shape in the tuple).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|[^\s]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_WHILE_RE = re.compile(r"\bwhile\(.*?body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?(?:to_apply|calls)=%([\w.\-]+)")
_COND_RE = re.compile(r"\bconditional\(.*")
_BRANCH_RE = re.compile(r"(?:branch_computations=\{([^}]*)\}|"
                        r"true_computation=%([\w.\-]+), false_computation=%([\w.\-]+))")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUP2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _max_shape_bytes(text: str) -> int:
    best = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n * _DT_BYTES[dt])
    return best


def _group_size(line: str) -> int:
    m = _GROUP2_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def analyze(hlo_text: str) -> dict:
    """Loop-aware collective byte totals (per-chip)."""
    comps: dict[str, dict] = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = {"coll": defaultdict(float), "counts": defaultdict(int),
                          "children": []}
            if raw.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        mw = _WHILE_RE.search(line)
        if mw:
            mt = _TRIP_RE.search(line)
            trip = int(mt.group(1)) if mt else 1
            comps[cur]["children"].append((mw.group(1), trip))
        mb = _BRANCH_RE.search(line)
        if mb:
            names = (mb.group(1).split(",") if mb.group(1)
                     else [mb.group(2), mb.group(3)])
            for n in names:
                n = n.strip().lstrip("%")
                if n:
                    comps[cur]["children"].append((n, 1))
        mc = _CALL_RE.search(line)
        if mc and "fusion(" not in line:
            comps[cur]["children"].append((mc.group(1), 1))
        ml = _COLL_RE.search(line)
        if ml and "-done" not in line.split("=")[1][:60]:
            shape_txt, kind = ml.group(1), ml.group(2)
            b = _max_shape_bytes(shape_txt)
            g = _group_size(line)
            w = {"all-gather": 1.0, "all-reduce": 2.0,
                 "reduce-scatter": float(g), "all-to-all": 1.0,
                 "collective-permute": 1.0}[kind]
            comps[cur]["coll"][kind] += b * w
            comps[cur]["counts"][kind] += 1

    totals = defaultdict(float)
    counts = defaultdict(int)
    loops = []

    def visit(name: str, mult: float, depth: int):
        c = comps.get(name)
        if c is None:
            return
        for kind, b in c["coll"].items():
            totals[kind] += b * mult
            counts[kind] += c["counts"][kind]
        for child, trip in c["children"]:
            if trip > 1:
                loops.append({"body": child, "trip": trip})
            visit(child, mult * trip, depth + 1)

    if entry:
        visit(entry, 1.0, 0)
    return {"by_op": dict(totals), "counts": dict(counts),
            "total": float(sum(totals.values())),
            "loops": loops[:32]}


def collective_bytes(hlo_text: str) -> dict:
    return analyze(hlo_text)
