"""Rule-based sharding policy (DESIGN.md §5).

Weights: Megatron-style tensor parallelism on the ``model`` axis — column-
parallel input projections (wi/wu/wq/wk/wv: output-feature dim on ``model``),
row-parallel output projections (wo/out_proj: reduction dim on ``model``) —
plus FSDP/ZeRO-style sharding of the remaining large dim over ``data`` so
grok-1-scale optimizer state fits. Every rule is divisibility-checked against
the actual mesh; anything that doesn't divide falls back gracefully
(non-divisible head counts like 24H or 40 experts over a 16-way axis never
produce uneven shards).

Sequence state (KV caches / SSM states): batch over ``data`` when divisible,
else the KV sequence dim (long_500k's batch=1 case) — context parallelism for
the half-megatoken cache; heads (or head_dim) over ``model``.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


ROW_PARALLEL = ("wo", "out_proj", "out")   # reduction dim sharded on model


def param_pspec(name: str, shape, data: int, model: int) -> P:
    nd = len(shape)
    if nd <= 1:
        return P()
    leaf = name.rsplit("/", 1)[-1]
    axes = [None] * nd
    # embeddings: vocab on model (keeps chunked-CE logits vocab-sharded)
    if leaf in ("embed", "unembed"):
        if shape[0] % model == 0:
            axes[0] = "model"
            if shape[1] % data == 0:
                axes[1] = "data"
        elif shape[1] % model == 0:
            axes[1] = "model"
        return P(*axes)
    row = any(leaf == r or leaf.endswith(r) for r in ROW_PARALLEL)
    prefer, other = (nd - 2, nd - 1) if row else (nd - 1, nd - 2)
    if shape[prefer] % model == 0:
        axes[prefer] = "model"
    elif shape[other] % model == 0:
        axes[other] = "model"
        prefer, other = other, prefer
    if axes[other] is None and shape[other] % data == 0:
        axes[other] = "data"
    return P(*axes)


def cache_pspec(name: str, shape, data: int, model: int, *,
                stacked: bool, decode: bool = False) -> P:
    nd = len(shape)
    axes = [None] * nd
    off = 1 if stacked else 0      # leading layer-group dim never sharded
    leaf = name.rsplit("/", 1)[-1]
    dims = list(range(off, nd))
    if not dims:
        return P()
    b = dims[0]
    if shape[b] % data == 0 and shape[b] > 1:
        axes[b] = "data"
    elif len(dims) > 1 and leaf in ("k", "v", "kpos") and shape[dims[1]] % data == 0:
        axes[dims[1]] = "data"     # context parallelism (batch=1 long decode)
    if decode and leaf in ("k", "v", "kpos") and len(dims) > 1:
        # flash-decode layout: shard the KV *sequence* on `model`. One query
        # token contracts over seq -> partial-softmax combines are tiny
        # all-reduces, vs all-gathering the whole cache under feature/head
        # sharding (3.3GB/step on internlm2 decode_32k; EXPERIMENTS §Perf).
        s = dims[1]
        if axes[s] is None and shape[s] % model == 0:
            axes[s] = "model"
            return P(*axes)
        if axes[s] == "data" and shape[s] % (data * model) == 0:
            axes[s] = ("data", "model")
            return P(*axes)
    # model axis: try trailing dims (heads, then head_dim/state)
    for d in (dims[2:] if leaf in ("k", "v") else dims[1:]):
        if axes[d] is None and shape[d] % model == 0 and shape[d] >= model:
            axes[d] = "model"
            break
    return P(*axes)


def _expand_data(spec: P, mesh) -> P:
    """Replace 'data' with the composite (pod, data) axes on multi-pod meshes."""
    das = data_axes(mesh)
    if das == ("data",):
        return spec
    return P(*[das if a == "data" else a for a in spec])


def _total_data(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= axis_size(mesh, a)
    return n


def params_shardings(param_shapes, mesh, *, fsdp: bool = True):
    """Pytree of NamedSharding for a params (or optimizer-state) pytree.

    fsdp=False drops the ``data``-axis shard on weights (pure tensor
    parallelism, weights replicated across data rows). Inference steps use
    this when the TP-sharded weights fit per-chip: FSDP's per-layer weight
    all-gather dominated decode collectives (3.4 of 3.6 GB/step on
    internlm2-1.8b decode_32k — EXPERIMENTS.md §Perf iteration 3)."""
    model = axis_size(mesh, "model")
    data = _total_data(mesh)

    def one(path, leaf):
        spec = param_pspec(_path_str(path), leaf.shape, data, model)
        if not fsdp:
            spec = P(*[a if a != "data" else None for a in spec])
        return NamedSharding(mesh, _expand_data(spec, mesh))

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def cache_shardings(cache_shapes, mesh, *, decode: bool = False):
    model = axis_size(mesh, "model")
    data = _total_data(mesh)

    def one(path, leaf):
        name = _path_str(path)
        stacked = name.startswith("groups")
        spec = cache_pspec(name, leaf.shape, data, model, stacked=stacked,
                           decode=decode)
        return NamedSharding(mesh, _expand_data(spec, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_sharding(shape, mesh, *, axes: str = "data"):
    """(B, ...) arrays: batch over the data axes (axes="data") or over the
    WHOLE mesh (axes="all" — pure-FSDP training, no tensor parallelism)."""
    names = (data_axes(mesh) + ("model",)) if axes == "all" else data_axes(mesh)
    n = 1
    for a in names:
        n *= axis_size(mesh, a)
    spec = P()
    if shape and shape[0] % n == 0 and shape[0] > 1:
        spec = P(names, *([None] * (len(shape) - 1)))
    elif shape and shape[0] % _total_data(mesh) == 0 and shape[0] > 1:
        spec = P(data_axes(mesh), *([None] * (len(shape) - 1)))
    return NamedSharding(mesh, spec)
