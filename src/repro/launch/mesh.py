"""Production meshes. TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips.

A FUNCTION (not a module constant) so importing this module never touches jax
device state — the dry-run sets XLA_FLAGS before first jax init; tests and
benches see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many devices exist (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[mesh.axis_names.index(name)]
