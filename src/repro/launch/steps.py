"""Step functions + abstract input specs for every (arch × input-shape) combo.

Shapes (assigned):
  train_4k     -> train_step   (fwd+bwd+AdamW, remat, chunked CE)
  prefill_32k  -> prefill_step (fill a cache of seq_len)
  decode_32k   -> serve_step   (ONE token against a seq_len cache)
  long_500k    -> serve_step   (batch=1, half-megatoken cache)

Modality conventions (DESIGN.md deviations):
  audio (enc-dec): seq_len splits 50/50 into encoder frames and decoder tokens;
  vlm: n_prefix_embeds patch embeddings + (seq_len - n_prefix) text tokens.

Everything returns ShapeDtypeStructs — no host allocation; the dry-run lowers
and compiles against these.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.launch.mesh import axis_size, data_axes
from repro.launch.sharding import (batch_sharding, cache_shardings,
                                   params_shardings)
from repro.models import encode, forward, init_cache, init_params
from repro.models.model import train_loss
from repro.training.optim import AdamW, apply_updates


# ======================================================================
# step functions


def make_train_step(cfg: ModelConfig, opt: AdamW):
    def step(params, opt_state, batch):
        def lf(p):
            loss, _ = train_loss(
                cfg, p, batch["tokens"], batch["targets"], batch["mask"],
                prefix_embeds=batch.get("prefix_embeds"),
                enc_embeds=batch.get("enc_embeds"), remat=True)
            return loss
        loss, grads = jax.value_and_grad(lf)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss
    return step


def make_prefill_step(cfg: ModelConfig):
    def step(params, tokens, cache, pos, prefix_embeds=None, enc_embeds=None):
        enc_out = None
        if cfg.is_encdec and enc_embeds is not None:
            enc_out = encode(cfg, params, enc_embeds)
        logits, cache, _ = forward(cfg, params, tokens, cache=cache, pos=pos,
                                   prefix_embeds=prefix_embeds,
                                   enc_out=enc_out)
        return logits, cache
    return step


def make_serve_step(cfg: ModelConfig):
    def step(params, token, cache, pos):
        logits, cache, _ = forward(cfg, params, token, cache=cache, pos=pos)
        return logits, cache
    return step


# ======================================================================
# abstract input specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def build(cfg: ModelConfig, shape_name: str, mesh, *,
          moment_dtype=jnp.bfloat16, activation_policy: str | None = None):
    """Returns dict(fn, args, in_shardings) ready for jit().lower()."""
    import os as _os

    from jax.sharding import PartitionSpec as _P

    from repro.models import model as _model_mod

    shp = INPUT_SHAPES[shape_name]
    # Activation-sharding policy at block boundaries (§Perf iterations 5-6):
    #   seqpar (train/prefill): Megatron-SP style — residual stream sharded
    #     (batch on data, SEQUENCE on model). chatglm train_4k: collective
    #     1479->787 GB/chip and temp 73->10.5 GB/chip (fits v5e HBM).
    #   batch (decode): S=1 can't shard; pin batch only.
    # MoE routing (top-k/scatter over the token axis) fights a model-sharded
    # sequence: granite-moe prefill_32k measured seqpar 6485 / none 2439 /
    # batch 810 GB-per-chip collectives (§Perf iteration 8) -> batch for MoE.
    default = ("batch" if (shp.kind == "decode" or cfg.is_moe) else "seqpar")
    policy = activation_policy or _os.environ.get("REPRO_ACT_POLICY", default)
    das = data_axes(mesh)
    from repro.models import attention as _attn_mod
    if policy == "batch":
        _model_mod.ACTIVATION_SPEC = _P(das, None, None)
    elif policy == "seqpar":
        _model_mod.ACTIVATION_SPEC = _P(das, "model", None)
    else:
        _model_mod.ACTIVATION_SPEC = None
    # hoist flash KV gathers out of the q-chunk loop (prefill/train only;
    # decode's KV stays sequence-sharded for the flash-decode layout)
    if shp.kind in ("prefill", "train") and policy != "none":
        _attn_mod.FLASH_KV_SPEC = _P(None, das, None, None, None)
    else:
        _attn_mod.FLASH_KV_SPEC = None
    B, S = shp.global_batch, shp.seq_len
    dt = jnp.dtype(cfg.dtype)

    p_structs = param_structs(cfg)
    # Inference: pure TP (replicate weights over data) when the TP shard fits
    # per chip — FSDP's per-layer weight all-gather dominates decode traffic.
    # Training (and grok-1-scale inference) keeps FSDP so optimizer state fits.
    from repro.launch.mesh import axis_size
    tp_bytes = 2 * cfg.param_count() / axis_size(mesh, "model")
    infer_fsdp = tp_bytes > 10e9
    p_shard = params_shardings(
        p_structs, mesh, fsdp=(shp.kind == "train" or infer_fsdp))
    bs = lambda s: batch_sharding(s, mesh)

    npfx = cfg.n_prefix_embeds if cfg.input_mode == "mixed" else 0
    enc_len = S // 2 if cfg.is_encdec else 0
    dec_len = S // 2 if cfg.is_encdec else S - npfx

    if shp.kind == "train":
        opt = AdamW(1e-4, moment_dtype=moment_dtype, weight_decay=0.1)
        o_structs = jax.eval_shape(opt.init, p_structs)
        # moments inherit the param rules (leaf names match); scalars replicate
        o_shard = params_shardings(o_structs, mesh)
        # NOTE (§Perf iter, REFUTED): sharding the batch over the whole mesh
        # ("pure FSDP", no TP) degenerated — the embedding gather can't keep a
        # 256-way batch shard, GSPMD replicated the batch and the MLP
        # all-reduces grew to full-batch f32 tensors. Kept on "data" axes;
        # activation sharding is pinned via with_sharding_constraint instead.
        bs = lambda s: batch_sharding(s, mesh, axes="data")  # noqa: E731
        batch = {"tokens": _sds((B, dec_len), jnp.int32),
                 "targets": _sds((B, dec_len), jnp.int32),
                 "mask": _sds((B, dec_len), jnp.float32)}
        bshard = {k: bs(v.shape) for k, v in batch.items()}
        if npfx:
            batch["prefix_embeds"] = _sds((B, npfx, cfg.d_model), dt)
            bshard["prefix_embeds"] = bs(batch["prefix_embeds"].shape)
        if cfg.is_encdec:
            batch["enc_embeds"] = _sds((B, enc_len, cfg.d_model), dt)
            bshard["enc_embeds"] = bs(batch["enc_embeds"].shape)
        fn = make_train_step(cfg, opt)
        return {"fn": fn,
                "args": (p_structs, o_structs, batch),
                "in_shardings": (p_shard, o_shard, bshard),
                "donate": (0, 1)}     # params/opt_state update in place

    cache_len = (dec_len + npfx) if shp.kind == "prefill" else (
        S // 2 if cfg.is_encdec else S)
    c_structs = jax.eval_shape(
        functools.partial(init_cache, cfg, B, int(cache_len),
                          enc_len=enc_len))
    c_shard = cache_shardings(c_structs, mesh, decode=(shp.kind == "decode"))
    pos = _sds((B,), jnp.int32)

    if shp.kind == "prefill":
        args = [p_structs, _sds((B, dec_len), jnp.int32), c_structs, pos]
        shards = [p_shard, bs((B, dec_len)), c_shard, bs((B,))]
        if npfx:
            args.append(_sds((B, npfx, cfg.d_model), dt))
            shards.append(bs((B, npfx, cfg.d_model)))
        elif cfg.is_encdec:
            args.append(None)
            shards.append(None)
        if cfg.is_encdec:
            args.append(_sds((B, enc_len, cfg.d_model), dt))
            shards.append(bs((B, enc_len, cfg.d_model)))
        fn = make_prefill_step(cfg)
        return {"fn": fn, "args": tuple(args), "in_shardings": tuple(shards),
                "donate": (2,)}       # cache filled in place

    # decode
    fn = make_serve_step(cfg)
    args = (p_structs, _sds((B, 1), jnp.int32), c_structs, pos)
    shards = (p_shard, bs((B, 1)), c_shard, bs((B,)))
    return {"fn": fn, "args": args, "in_shardings": shards, "donate": (2,)}
