"""Mixture-of-Experts: capacity-based top-k routing with gather/scatter dispatch.

GShard's one-hot dispatch einsum costs O(B·S²·K·D) FLOPs at practical capacity
(it contracts a (S, E, C) dispatch tensor against activations), which would
dominate the roofline at seq 4k. We instead build an explicit slot→token index
map and dispatch with gathers (O(tokens·D) bytes, ~0 FLOPs), the way production
TPU MoE stacks do ragged dispatch. Routing is per batch row, so under a
batch-sharded mesh the dispatch is shard-local; expert-internal d_ff shards on
the ``model`` axis (expert counts 40/8 don't divide 16 — DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_init(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=0.02, dtype=jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), dtype=dtype),
        "wu": dense_init(ks[2], (e, d, f), dtype=dtype),
        "wo": dense_init(ks[3], (e, f, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(k1, (d, fs), dtype=dtype),
            "wu": dense_init(k2, (d, fs), dtype=dtype),
            "wo": dense_init(k3, (fs, d), dtype=dtype),
        }
    return p


def _capacity(cfg, tokens_per_row: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * tokens_per_row / cfg.n_experts)
    return max(1, min(c, tokens_per_row))  # a token hits K *distinct* experts


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (out (B, S, D), aux dict with load-balance loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)
    TK = S * K

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                 # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment, per batch row ---
    e_flat = gate_idx.reshape(B, TK)                              # expert id per (s,k)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)               # (B,TK,E)
    pos = jnp.cumsum(oh, axis=1) - oh
    pos_in_expert = jnp.sum(pos * oh, axis=-1)                    # (B,TK)
    keep = pos_in_expert < C

    # --- slot -> token map via scatter (OOB slots dropped) ---
    # vmapped over batch: a single scatter with batch-carrying indices makes
    # GSPMD replicate the whole dispatch tensor (64GB f32 all-gathers per
    # layer on granite-moe prefill_32k — EXPERIMENTS §Perf iteration 8);
    # vmap marks B as a parallel batch dim so the scatter stays shard-local.
    slot = jnp.where(keep, e_flat * C + pos_in_expert, E * C)     # E*C = drop sentinel
    src = jnp.arange(TK, dtype=jnp.int32)

    def _row_scatter(slot_row):
        return jnp.full((E * C,), TK, jnp.int32).at[slot_row].set(
            src, mode="drop")

    token_of_slot = jax.vmap(_row_scatter)(slot)
    slot_valid = token_of_slot < TK                               # (B,E*C)
    src_tok = jnp.minimum(token_of_slot // K, S - 1)

    # --- dispatch: gather token activations into expert slots ---
    exp_in = jnp.take_along_axis(x, src_tok[..., None], axis=1)   # (B,E*C,D)
    exp_in = jnp.where(slot_valid[..., None], exp_in, 0)
    exp_in = exp_in.reshape(B, E, C, D)

    g = jnp.einsum("becd,edf->becf", exp_in, p["wi"])
    u = jnp.einsum("becd,edf->becf", exp_in, p["wu"])
    h = jax.nn.silu(g) * u
    exp_out = jnp.einsum("becf,efd->becd", h, p["wo"]).reshape(B, E * C, D)

    # --- combine: gather each (token, k)'s slot output, weight, and sum over k ---
    gathered = jnp.take_along_axis(exp_out, jnp.minimum(slot, E * C - 1)[..., None],
                                   axis=1)                        # (B,TK,D)
    gathered = jnp.where(keep[..., None], gathered, 0)
    w = gate_vals.reshape(B, TK)[..., None].astype(gathered.dtype)
    out = (gathered * w).reshape(B, S, K, D).sum(axis=2)

    if "shared" in p:
        sp = p["shared"]
        gs = jnp.einsum("bsd,df->bsf", x, sp["wi"])
        us = jnp.einsum("bsd,df->bsf", x, sp["wu"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * us, sp["wo"])

    me = probs.mean(axis=(0, 1))                                  # (E,)
    ce = oh.reshape(B, S, K, E).sum(2).astype(jnp.float32).mean(axis=(0, 1))
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "dropped_frac": 1.0 - keep.astype(jnp.float32).mean()}
    return out.astype(x.dtype), aux
