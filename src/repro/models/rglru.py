"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Temporal-mixing block: x -> {linear gate branch (GeLU), linear recurrent
branch -> causal conv -> RG-LRU} -> elementwise product -> out proj.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a h_in + b_a);  i_t = sigmoid(W_x h_in + b_x)
    a_t = exp(-c * softplus(Λ) * r_t)          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Linear recurrence h_t = a_t h_{t-1} + b_t is evaluated with
``lax.associative_scan`` for prefill (O(log S) depth) and a single fused step
for decode. The recurrent state (+ conv state) is the shared "sequence state"
for PrefillShare on this hybrid architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

_C = 8.0


def rglru_width(cfg):
    return cfg.rglru_width or cfg.d_model


def rglru_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    w = rglru_width(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_x": dense_init(ks[0], (d, w), dtype=dtype),     # recurrent branch
        "in_gate": dense_init(ks[1], (d, w), dtype=dtype),  # gelu gate branch
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], (w, w), scale=0.02, dtype=dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[4], (w, w), scale=0.02, dtype=dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 0.65, jnp.float32),           # softplus(Λ) init ~ decay 0.9^c
        "out": dense_init(jax.random.fold_in(key, 7), (w, d), dtype=dtype),
    }


def init_rglru_cache(cfg, batch, dtype):
    w = rglru_width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def _conv(x, w, b, state):
    W = w.shape[0]
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return out + b, new_state


def rglru_apply(p, x, cfg, cache=None):
    """x: (B,S,D) -> (out, new_cache)."""
    B, S, D = x.shape
    w = rglru_width(cfg)
    xr = jnp.einsum("bsd,dw->bsw", x, p["in_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_gate"]))

    conv_state = cache["conv"] if cache is not None else jnp.zeros(
        (B, cfg.conv_width - 1, w), x.dtype)
    xc, new_conv = _conv(xr, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["w_a"]).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["w_i"]).astype(jnp.float32)
                       + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r                  # (B,S,W), negative
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xc.astype(jnp.float32))

    h0 = cache["h"] if cache is not None else jnp.zeros((B, w), jnp.float32)
    if S == 1:
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None]
        new_h = h
    else:
        # fold initial state into the first step, then associative scan
        b = b.at[:, 0].add(a[:, 0] * h0)

        def combine(lt, r_):
            al, bl = lt
            ar, br = r_
            return al * ar, br + ar * bl

        _, hs = lax.associative_scan(combine, (a, b), axis=1)
        new_h = hs[:, -1]

    out = jnp.einsum("bsw,wd->bsd", (hs.astype(x.dtype) * gate), p["out"])
    return out, {"h": new_h, "conv": new_conv}
