"""Attention: GQA with RoPE variants, sliding windows, softcaps, caches.

Two execution paths share one mask semantics:
  - ``_direct``: materialized scores, for small shapes (CPU smoke tests, decode).
  - ``_flash``: chunked online-softmax (flash-style) in pure JAX ``lax.scan`` /
    ``lax.map`` — memory O(chunk), used for large prefill/train shapes. The
    Pallas TPU kernel (repro.kernels.flash_prefill) implements the same
    contract for real-TPU deployment; this is the XLA-lowerable twin used by
    the multi-pod dry-run.

Positions are explicit: ``q_pos`` (B, Sq) and ``k_pos`` (B, Tk) absolute token
positions; ``k_pos = -1`` marks invalid (unwritten) cache slots. Causality,
sliding windows, and cache validity all derive from these arrays, which makes
full prefill, *partial* prefill (PrefillShare's incremental extension), and
single-token decode the same code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LOCAL_ATTN
from repro.models.layers import dense_init, rmsnorm
from repro.models.rope import apply_rope

_NEG = -1e30

# Distributed policy hook (set by repro.launch.steps): PartitionSpec for the
# flash path's chunked K/V (nk, B, Ck, Hkv, D). Pinning these batch-sharded /
# head-replicated hoists the KV all-gather OUT of the q-chunk loop — GSPMD
# otherwise re-gathers model-sharded KV on every loop iteration (32x per
# layer at 32k prefill; EXPERIMENTS.md §Perf iteration 7).
FLASH_KV_SPEC = None


def _constrain_kv(x):
    if FLASH_KV_SPEC is not None:
        return jax.lax.with_sharding_constraint(x, FLASH_KV_SPEC)
    return x


def _pick_chunk(n: int, target: int) -> int:
    c = min(n, target)
    while n % c:
        c -= 1
    return c


def _block_mask(qp, kp, window):
    """qp: (B, Cq), kp: (B, Ck) -> bool (B, 1, 1, Cq, Ck)."""
    m = (kp[:, None, :] <= qp[:, :, None]) & (kp[:, None, :] >= 0)
    if window:
        m &= kp[:, None, :] > (qp[:, :, None] - window)
    return m[:, None, None, :, :]


def _softcap(s, cap):
    return jnp.tanh(s / cap) * cap if cap else s


def _direct(qg, k, v, q_pos, k_pos, window, softcap):
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = _softcap(s, softcap)
    mask = _block_mask(q_pos, k_pos, window)
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.astype(v.dtype)


def _flash_fwd_impl(qg, k, v, q_pos, k_pos, window, softcap, q_chunk, kv_chunk):
    """Returns (o (B,Sq,Hkv,G,D), lse (B,Hkv,G,Sq))."""
    B, Sq, Hkv, G, D = qg.shape
    Tk = k.shape[1]
    Cq = _pick_chunk(Sq, q_chunk)
    Ck = _pick_chunk(Tk, kv_chunk)
    nq, nk = Sq // Cq, Tk // Ck

    kc = _constrain_kv(jnp.moveaxis(k.reshape(B, nk, Ck, Hkv, D), 1, 0))
    vc = _constrain_kv(jnp.moveaxis(v.reshape(B, nk, Ck, Hkv, D), 1, 0))
    kpc = jnp.moveaxis(k_pos.reshape(B, nk, Ck), 1, 0)

    def q_block(args):
        qb, qp = args  # (B, Cq, Hkv, G, D), (B, Cq)

        def kv_step(carry, xs):
            m, lden, acc = carry
            kb, vb, kpb = xs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32))
            s = _softcap(s, softcap)
            mask = _block_mask(qp, kpb, window)
            s = jnp.where(mask, s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask  # mask kills fully-masked rows
            corr = jnp.exp(m - m_new)
            lden = lden * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, lden, acc), None

        m0 = jnp.full((B, Hkv, G, Cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, Cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, Cq, D), jnp.float32)
        (m, lden, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpc))
        o = acc / jnp.maximum(lden, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(lden, 1e-30))           # (B,Hkv,G,Cq)
        return jnp.moveaxis(o, 3, 1).astype(v.dtype), lse

    if nq == 1:
        return q_block((qg, q_pos))
    qs = jnp.moveaxis(qg.reshape(B, nq, Cq, Hkv, G, D), 1, 0)
    qps = jnp.moveaxis(q_pos.reshape(B, nq, Cq), 1, 0)
    out, lses = lax.map(q_block, (qs, qps))     # (nq, B, Cq, Hkv, G, D)
    o = jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hkv, G, D)
    lse = jnp.moveaxis(lses, 0, -2).reshape(B, Hkv, G, Sq)
    return o, lse


def _flash_bwd_impl(qg, k, v, q_pos, k_pos, o, lse, do,
                    window, softcap, q_chunk, kv_chunk):
    """Standard flash backward: recompute p per block from (q,k,lse); only
    (o, lse) were saved. Accumulates dk/dv across q blocks in a scan carry."""
    B, Sq, Hkv, G, D = qg.shape
    Tk = k.shape[1]
    Cq = _pick_chunk(Sq, q_chunk)
    Ck = _pick_chunk(Tk, kv_chunk)
    nq, nk = Sq // Cq, Tk // Ck

    kc = _constrain_kv(jnp.moveaxis(k.reshape(B, nk, Ck, Hkv, D), 1, 0))
    vc = _constrain_kv(jnp.moveaxis(v.reshape(B, nk, Ck, Hkv, D), 1, 0))
    kpc = jnp.moveaxis(k_pos.reshape(B, nk, Ck), 1, 0)

    qs = jnp.moveaxis(qg.reshape(B, nq, Cq, Hkv, G, D), 1, 0)
    qps = jnp.moveaxis(q_pos.reshape(B, nq, Cq), 1, 0)
    dos = jnp.moveaxis(do.reshape(B, nq, Cq, Hkv, G, D), 1, 0)
    os_ = jnp.moveaxis(o.reshape(B, nq, Cq, Hkv, G, D), 1, 0)
    lses = jnp.moveaxis(lse.reshape(B, Hkv, G, nq, Cq), 3, 0)  # (nq,B,H,G,Cq)

    def q_step(carry, xs):
        dk_acc, dv_acc = carry
        qb, qp, dob, ob, lseb = xs
        dof = dob.astype(jnp.float32)
        of = ob.astype(jnp.float32)
        Drow = jnp.einsum("bqhgd,bqhgd->bhgq", dof, of)        # (B,H,G,Cq)

        def kv_step(carry2, xs2):
            dq_b, dk_acc, dv_acc, j = carry2
            kb, vb, kpb = xs2
            s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                               kb.astype(jnp.float32))
            s = _softcap(s_raw, softcap)
            mask = _block_mask(qp, kpb, window)
            s = jnp.where(mask, s, _NEG)
            p = jnp.exp(s - lseb[..., None]) * mask            # (B,H,G,Cq,Ck)
            dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, dof)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dof, vb.astype(jnp.float32))
            ds = p * (dp - Drow[..., None])
            if softcap:
                t = jnp.tanh(s_raw / softcap)
                ds = ds * (1.0 - t * t)
            dq_b = dq_b + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                     kb.astype(jnp.float32))
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb.astype(jnp.float32))
            dk_acc = lax.dynamic_update_slice(
                dk_acc, lax.dynamic_slice(dk_acc, (0, j * Ck, 0, 0),
                                          (B, Ck, Hkv, D)) + dk_blk,
                (0, j * Ck, 0, 0))
            dv_acc = lax.dynamic_update_slice(
                dv_acc, lax.dynamic_slice(dv_acc, (0, j * Ck, 0, 0),
                                          (B, Ck, Hkv, D)) + dv_blk,
                (0, j * Ck, 0, 0))
            return (dq_b, dk_acc, dv_acc, j + 1), None

        dq0 = jnp.zeros((B, Cq, Hkv, G, D), jnp.float32)
        (dq_b, dk_acc, dv_acc, _), _ = lax.scan(
            kv_step, (dq0, dk_acc, dv_acc, 0), (kc, vc, kpc))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((B, Tk, Hkv, D), jnp.float32)
    dv0 = jnp.zeros((B, Tk, Hkv, D), jnp.float32)
    (dk, dv), dqs = lax.scan(q_step, (dk0, dv0), (qs, qps, dos, os_, lses))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, Hkv, G, D)
    return dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


import functools as _ft

import numpy as _np


@_ft.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash_vjp(window, softcap, q_chunk, kv_chunk, qg, k, v, q_pos, k_pos):
    o, _ = _flash_fwd_impl(qg, k, v, q_pos, k_pos, window, softcap,
                           q_chunk, kv_chunk)
    return o


def _flash_vjp_fwd(window, softcap, q_chunk, kv_chunk, qg, k, v, q_pos, k_pos):
    o, lse = _flash_fwd_impl(qg, k, v, q_pos, k_pos, window, softcap,
                             q_chunk, kv_chunk)
    return o, (qg, k, v, q_pos, k_pos, o, lse)


def _flash_vjp_bwd(window, softcap, q_chunk, kv_chunk, res, do):
    qg, k, v, q_pos, k_pos, o, lse = res
    dq, dk, dv = _flash_bwd_impl(qg, k, v, q_pos, k_pos, o, lse, do,
                                 window, softcap, q_chunk, kv_chunk)
    zq = _np.zeros(q_pos.shape, jax.dtypes.float0)   # int args: no cotangent
    zk = _np.zeros(k_pos.shape, jax.dtypes.float0)
    return dq, dk, dv, zq, zk


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _flash(qg, k, v, q_pos, k_pos, window, softcap, q_chunk, kv_chunk):
    """Differentiable flash attention: custom VJP stores only (o, lse)."""
    return _flash_vjp(window, softcap, q_chunk, kv_chunk, qg, k, v,
                      q_pos, k_pos)


def attention(q, k, v, q_pos, k_pos, *, window: int = 0, softcap=None,
              scale=None, q_chunk: int = 1024, kv_chunk: int = 2048,
              force_flash: bool | None = None):
    """q: (B,Sq,Hq,D); k/v: (B,Tk,Hkv,D); returns (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = (q * (scale if scale is not None else D ** -0.5)).reshape(B, Sq, Hkv, G, D)
    use_flash = force_flash if force_flash is not None else (Sq * Tk > 4096 * 2048)
    if use_flash:
        o = _flash(qg, k, v, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32),
                   window, softcap, q_chunk, kv_chunk)
    else:
        o = _direct(qg, k, v, q_pos, k_pos, window, softcap)
    return o.reshape(B, Sq, Hq, D)


# ======================================================================
# Paged decode attention (shared-pool data plane)

PAGED_CACHE_KEYS = ("k_pages", "v_pages", "block_tables")


def paged_attention_step(q, k_pages, v_pages, block_tables, lengths, *,
                         softcap=0.0):
    """One decode step of attention over the paged KV pool.

    q: (B, Hq, D); k/v_pages: (P, page, Hkv, D); block_tables: (B, npages);
    lengths: (B,). Mosaic kernel on TPU; elsewhere the pure-jnp gather twin
    (kernels.ref.ref_paged_decode) — same contract, XLA-lowerable, and
    bit-compatible with the ``_direct`` dense path so paged and dense engines
    produce identical greedy tokens.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels.paged_decode import paged_decode_attention
        return paged_decode_attention(q, k_pages, v_pages, block_tables,
                                      lengths, softcap=softcap or 0.0)
    from repro.kernels.ref import ref_paged_decode
    return ref_paged_decode(q, k_pages, v_pages, block_tables, lengths,
                            softcap=softcap or 0.0)


def paged_prefill_attention(q, k_pages, v_pages, block_tables, start, *,
                            softcap=0.0):
    """Chunk-prefill attention over the paged KV pool.

    q: (B, S, Hq, D) chunk queries at absolute positions ``start[b] + i``;
    the chunk's own K/V rows are already in their pages. Mosaic kernel on
    TPU; elsewhere the pure-jnp gather twin (kernels.ref.ref_paged_prefill)
    — same contract and bit-compatible with the ``_direct`` dense path, so
    chunked and unchunked prefill produce identical greedy tokens.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels.flash_prefill_paged import flash_prefill_paged
        return flash_prefill_paged(q, k_pages, v_pages, block_tables, start,
                                   softcap=softcap or 0.0)
    from repro.kernels.ref import ref_paged_prefill
    return ref_paged_prefill(q, k_pages, v_pages, block_tables, start,
                             softcap=softcap or 0.0)


def _paged_apply(p, q, k, v, cache, pos, cfg):
    """Scatter the incoming tokens' K/V into their (private) pool pages,
    then attend over the block table. q/k/v: post-rope (B, S, H, D).

    S == 1 is the decode step (paged_attention_step); S > 1 is a prefill
    chunk (paged_prefill_attention) — both read the prefix straight from the
    pages, no dense gather.
    """
    B, S = q.shape[0], q.shape[1]
    kp, vp, bt = (cache[key] for key in PAGED_CACHE_KEYS)
    page = kp.shape[1]
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None]   # (B, S)
    pg = jnp.take_along_axis(bt, positions // page, axis=1)           # (B, S)
    slot = positions % page
    # vectorized scatter; written pages are private per sequence (fresh
    # chunk pages / copy-on-write at handoff), so (pg, slot) never collide.
    kp = kp.at[pg, slot].set(k)
    vp = vp.at[pg, slot].set(v)
    if S == 1:
        o = paged_attention_step(q[:, 0], kp, vp, bt, pos + 1,
                                 softcap=cfg.attn_softcap)[:, None]
    else:
        o = paged_prefill_attention(q, kp, vp, bt, pos,
                                    softcap=cfg.attn_softcap)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])
    return out, {"k_pages": kp, "v_pages": vp, "block_tables": bt}


# ======================================================================
# Attention block: projections + rope + cache plumbing


def attn_init(key, cfg, *, cross: bool = False, dtype=jnp.float32):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype=dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_attn_cache(cfg, kind, batch, cache_len, dtype):
    """KV caches store a FLATTENED (kv_heads * head_dim) feature dim: a single
    named mesh axis can shard it 16-way even when kv_heads (8, 2, 1, ...)
    doesn't divide the axis — GSPMD then splits the reshape to (H, D) as
    (H-ways, D-ways) natively instead of involuntarily rematerializing
    (observed as a 2.2GB/step all-gather before this layout; EXPERIMENTS §Perf).
    """
    t = cache_len
    if kind == LOCAL_ATTN and cfg.sliding_window:
        t = min(cache_len, cfg.sliding_window)
    shape = (batch, t, cfg.n_kv_heads * cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "kpos": jnp.full((batch, t), -1, jnp.int32),
    }


def _update_global(cache, k, v, q_pos, pos):
    upd_kv = jax.vmap(lambda c, u, p: lax.dynamic_update_slice(c, u, (p, 0)))
    upd_p = jax.vmap(lambda c, u, p: lax.dynamic_update_slice(c, u, (p,)))
    return {
        "k": upd_kv(cache["k"], k, pos),
        "v": upd_kv(cache["v"], v, pos),
        "kpos": upd_p(cache["kpos"], q_pos, pos),
    }


def _update_window(cache, k, v, q_pos):
    t = cache["k"].shape[1]
    s = k.shape[1]
    if s >= t:
        return {"k": k[:, -t:], "v": v[:, -t:], "kpos": q_pos[:, -t:]}
    cat = lambda c, u: jnp.concatenate([c[:, s:], u], axis=1)
    return {"k": cat(cache["k"], k), "v": cat(cache["v"], v),
            "kpos": cat(cache["kpos"], q_pos)}


def attn_apply(p, x, cfg, kind, *, cache=None, pos=None, enc_out=None,
               cross: bool = False, causal: bool = True,
               flash: bool | None = None):
    """One attention layer.

    x: (B, S, D). pos: (B,) starting absolute position of x's first token.
    cache: attention cache dict or None (pure self-attention over x).
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window if kind == LOCAL_ATTN else 0

    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, hq, hd)
    if cross:
        # keys/values come from the encoder output; prefill (enc_out given)
        # computes and caches them, decode (enc_out=None) reuses the cache.
        if enc_out is not None:
            kf = jnp.einsum("bsd,de->bse", enc_out, p["wk"])
            vf = jnp.einsum("bsd,de->bse", enc_out, p["wv"])
            new_cache = {"k": kf, "v": vf}
        else:
            kf, vf = cache["k"], cache["v"]
            new_cache = cache
        k = kf.reshape(B, -1, hkv, hd)
        v = vf.reshape(B, -1, hkv, hd)
        tk = k.shape[1]
        q_pos = jnp.full((B, S), jnp.iinfo(jnp.int32).max, jnp.int32)
        k_pos = jnp.broadcast_to(jnp.arange(tk, dtype=jnp.int32)[None], (B, tk))
        o = attention(q, k, v, q_pos, k_pos, force_flash=flash)
        return jnp.einsum("bse,ed->bsd", o.reshape(B, S, hq * hd), p["wo"]), new_cache

    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, hkv, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, hkv, hd)

    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    if pos is None:
        pos = jnp.zeros((B,), jnp.int32)
    q_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q = apply_rope(q, q_pos, style=cfg.rope_style, theta=cfg.rope_theta)
    k = apply_rope(k, q_pos, style=cfg.rope_style, theta=cfg.rope_theta)

    if cache is not None and "k_pages" in cache:
        if kind == LOCAL_ATTN:
            raise NotImplementedError("paged cache requires global attention")
        return _paged_apply(p, q, k, v, cache, pos, cfg)

    if cache is None:
        mask_qpos = q_pos if causal else jnp.full_like(
            q_pos, jnp.iinfo(jnp.int32).max)
        o = attention(q, k, v, mask_qpos, q_pos, window=window,
                      softcap=cfg.attn_softcap, force_flash=flash)
        new_cache = None
    else:
        kf = k.reshape(B, S, hkv * hd)
        vf = v.reshape(B, S, hkv * hd)
        if kind == LOCAL_ATTN and cfg.sliding_window:
            new_cache = _update_window(cache, kf, vf, q_pos)
        else:
            new_cache = _update_global(cache, kf, vf, q_pos, pos)
        t = new_cache["k"].shape[1]
        o = attention(q, new_cache["k"].reshape(B, t, hkv, hd),
                      new_cache["v"].reshape(B, t, hkv, hd),
                      q_pos, new_cache["kpos"],
                      window=window, softcap=cfg.attn_softcap, force_flash=flash)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, hq * hd), p["wo"])
    return out, new_cache
