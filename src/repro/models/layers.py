"""Primitive layers: init helpers, RMSNorm, embedding, gated MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish), matching common LLM inits."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def rmsnorm_init(dim, dtype=jnp.float32):
    return jnp.zeros((dim,), dtype)  # stored as (scale - 1), gemma-style


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff), dtype=dtype),      # gate
        "wu": dense_init(k2, (d_model, d_ff), dtype=dtype),      # up
        "wo": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp_apply(p, x):
    """SwiGLU gated MLP."""
    g = jnp.einsum("...d,df->...f", x, p["wi"])
    u = jnp.einsum("...d,df->...f", x, p["wu"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["wo"])


def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return dense_init(key, (vocab, d_model), scale=0.02, dtype=dtype)


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table, softcap: float | None = None):
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
