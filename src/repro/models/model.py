"""Composable model: one stack covering dense / MoE / SSM / hybrid / enc-dec / VLM.

Layers are organized as repetitions of ``cfg.layer_pattern`` ("groups"). All
full groups are stacked on a leading axis and executed with ``lax.scan`` so HLO
size is O(1) in depth (an 80-layer model compiles in seconds); a remainder
"tail" (e.g. recurrentgemma's 26 = 8*3 + 2) runs unrolled. Caches mirror the
same structure, which makes the whole sequence state a single pytree — exactly
the object PrefillShare hands off between prefill and decode workers.

The unified ``forward(params, tokens, cache, pos)`` covers:
  - training forward (cache=None),
  - full prefill (pos=0, empty cache),
  - PARTIAL prefill (pos>0: extend an existing cache with appended tokens),
  - decode (S=1),
which is the paper's execution pipeline (§3.3) expressed as one function.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, SSD, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (embed_init, embed_lookup, mlp_apply, mlp_init,
                                 rmsnorm, rmsnorm_init, unembed)

Params = Any
Cache = Any

# Distributed activation policy, set by repro.launch.steps before tracing:
# a PartitionSpec applied to the (B, S, D) residual stream at block
# boundaries via with_sharding_constraint (pins GSPMD propagation; see
# EXPERIMENTS.md §Perf). None = single-host, no constraint.
ACTIVATION_SPEC = None


def _constrain(x):
    if ACTIVATION_SPEC is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, ACTIVATION_SPEC)
    return x


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _group_structure(cfg: ModelConfig):
    pat = cfg.layer_pattern
    n_full = cfg.n_layers // len(pat)
    tail = tuple(pat[: cfg.n_layers % len(pat)])
    return pat, n_full, tail


# ======================================================================
# init


def _layer_init(key, cfg, kind, *, cross: bool, dtype):
    ks = jax.random.split(key, 8)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype=dtype)
    elif kind == RGLRU:
        p["rglru"] = rglru_mod.rglru_init(ks[0], cfg, dtype=dtype)
    elif kind == SSD:
        p["ssd"] = ssd_mod.ssd_init(ks[0], cfg, dtype=dtype)
    if cross:
        p["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn_mod.attn_init(ks[1], cfg, cross=True, dtype=dtype)
    if cfg.d_ff > 0 and kind != SSD:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.is_moe and kind in (ATTN, LOCAL_ATTN):
            p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype=dtype)
        else:
            p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = _dtype(cfg)
    pat, n_full, tail = _group_structure(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.is_encdec

    def stacked(kf, kind, pos):
        def one(k):
            return _layer_init(k, cfg, kind, cross=cross, dtype=dtype)
        return jax.vmap(one)(jax.random.split(jax.random.fold_in(kf, pos), n_full))

    params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "groups": {f"pos{i}": stacked(keys[1], kind, i) for i, kind in enumerate(pat)}
        if n_full else {},
        "tail": [
            _layer_init(jax.random.fold_in(keys[2], i), cfg, kind,
                        cross=cross, dtype=dtype)
            for i, kind in enumerate(tail)
        ],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[3], cfg.vocab_size, cfg.d_model, dtype)
    if cfg.is_encdec:
        def enc_stack(k):
            def one(kk):
                return _layer_init(kk, cfg, ATTN, cross=False, dtype=dtype)
            return jax.vmap(one)(jax.random.split(k, cfg.encoder_layers))
        params["encoder"] = {"groups": enc_stack(keys[4]),
                             "norm": rmsnorm_init(cfg.d_model, dtype)}
    return params


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None, enc_len: int = 0) -> Cache:
    dtype = dtype or _dtype(cfg)
    pat, n_full, tail = _group_structure(cfg)

    def layer_cache(kind):
        if kind in (ATTN, LOCAL_ATTN):
            c = attn_mod.init_attn_cache(cfg, kind, batch, cache_len, dtype)
        elif kind == RGLRU:
            c = rglru_mod.init_rglru_cache(cfg, batch, dtype)
        elif kind == SSD:
            c = ssd_mod.init_ssd_cache(cfg, batch, dtype)
        else:
            raise ValueError(kind)
        if cfg.is_encdec:
            f = cfg.n_kv_heads * cfg.head_dim   # flattened (see init_attn_cache)
            c["cross"] = {"k": jnp.zeros((batch, enc_len, f), dtype),
                          "v": jnp.zeros((batch, enc_len, f), dtype)}
        return c

    def stacked(kind):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape), layer_cache(kind))

    return {
        "groups": {f"pos{i}": stacked(kind) for i, kind in enumerate(pat)}
        if n_full else {},
        "tail": [layer_cache(kind) for kind in tail],
    }


# ======================================================================
# forward


def _apply_layer(lp, x, cfg, kind, cache, pos, enc_out, flash, causal=True):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    aux = {}
    if kind in (ATTN, LOCAL_ATTN):
        acache = None
        if cache is not None:
            keys = (attn_mod.PAGED_CACHE_KEYS if "k_pages" in cache
                    else ("k", "v", "kpos"))
            acache = {k: cache[k] for k in keys}
        out, new_acache = attn_mod.attn_apply(
            lp["attn"], h, cfg, kind, cache=acache, pos=pos, causal=causal,
            flash=flash)
        new_cache = dict(cache) if cache is not None else None
        if new_cache is not None and new_acache is not None:
            new_cache.update(new_acache)
    elif kind == RGLRU:
        sub = None if cache is None else {"h": cache["h"], "conv": cache["conv"]}
        out, nc = rglru_mod.rglru_apply(lp["rglru"], h, cfg, cache=sub)
        new_cache = dict(cache) if cache is not None else None
        if new_cache is not None:
            new_cache.update(nc)
    elif kind == SSD:
        sub = None if cache is None else {"ssm": cache["ssm"], "conv": cache["conv"]}
        out, nc = ssd_mod.ssd_apply(lp["ssd"], h, cfg, cache=sub)
        new_cache = dict(cache) if cache is not None else None
        if new_cache is not None:
            new_cache.update(nc)
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in lp:
        hx = rmsnorm(x, lp["norm_x"], cfg.norm_eps)
        ccache = cache.get("cross") if cache is not None else None
        # use cached cross-KV when it has been populated (prefill writes it)
        out, new_cc = attn_mod.attn_apply(
            lp["cross"], hx, cfg, ATTN, cache=ccache, enc_out=enc_out,
            cross=True, flash=flash)
        x = x + out
        if new_cache is not None:
            new_cache["cross"] = new_cc

    if "norm2" in lp:
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if "moe" in lp:
            out2, aux = moe_mod.moe_apply(lp["moe"], h2, cfg)
        else:
            out2 = mlp_apply(lp["mlp"], h2)
        x = x + out2
    return x, new_cache, aux


def _aux_zero():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "dropped_frac": jnp.zeros((), jnp.float32)}


def _aux_add(a, b):
    if not b:
        return a
    return {k: a[k] + jnp.asarray(b.get(k, 0.0), jnp.float32) for k in a}


def encode(cfg: ModelConfig, params: Params, embeds, flash=None):
    """Bidirectional encoder over precomputed frame embeddings (audio stub)."""
    x = embeds.astype(_dtype(cfg))
    enc = params["encoder"]

    def body(x, lp):
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        # bidirectional: all positions valid for all queries
        out, _ = attn_mod.attn_apply(lp["attn"], h, cfg, ATTN, cache=None,
                                     pos=None, causal=False, flash=flash)
        x = x + out
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h2)
        return x, None

    x, _ = lax.scan(body, x, enc["groups"])
    return rmsnorm(x, enc["norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: Params, tokens, *, cache: Optional[Cache] = None,
            pos=None, prefix_embeds=None, enc_out=None, logits: str = "last",
            flash: Optional[bool] = None, remat: bool = False):
    """Run the decoder stack.

    tokens: (B, S) int32 (ignored for pure-embeds input). pos: (B,) absolute
    position of tokens[:, 0] (None -> zeros). Returns (output, new_cache, aux):
    output is last-token logits (B, V), all logits (B, S, V), or hidden states
    (B, S, D) depending on ``logits`` in {"last", "all", "hidden"}.
    """
    dtype = _dtype(cfg)
    if cfg.input_mode == "mixed" and prefix_embeds is not None:
        xt = embed_lookup(params["embed"], tokens) * jnp.asarray(
            cfg.d_model ** 0.5, dtype)
        x = jnp.concatenate([prefix_embeds.astype(dtype), xt], axis=1)
    elif cfg.input_mode == "embeds" and prefix_embeds is not None and not cfg.is_encdec:
        x = prefix_embeds.astype(dtype)
    else:
        x = embed_lookup(params["embed"], tokens) * jnp.asarray(
            cfg.d_model ** 0.5, dtype)

    B = x.shape[0]
    if pos is None:
        pos = jnp.zeros((B,), jnp.int32)

    pat, n_full, tail = _group_structure(cfg)
    aux = _aux_zero()

    def group_body(x, slc):
        gp, gc = slc
        a = _aux_zero()
        new_gc = {} if gc is not None else None
        for i, kind in enumerate(pat):
            ci = gc[f"pos{i}"] if gc is not None else None
            x = _constrain(x)
            x, nc, ax = _apply_layer(gp[f"pos{i}"], x, cfg, kind, ci, pos,
                                     enc_out, flash)
            a = _aux_add(a, ax)
            if new_gc is not None:
                new_gc[f"pos{i}"] = nc
        return x, (new_gc, a)

    if n_full:
        body = jax.checkpoint(group_body) if remat else group_body
        gp = params["groups"]
        gc = cache["groups"] if cache is not None else None
        x, (new_groups, auxs) = lax.scan(body, x, (gp, gc))
        aux = jax.tree.map(lambda a: a.sum(0), auxs)
    else:
        new_groups = {}

    new_tail = []
    for i, kind in enumerate(tail):
        ci = cache["tail"][i] if cache is not None else None
        lp = params["tail"][i]
        x, nc, ax = _apply_layer(lp, x, cfg, kind, ci, pos, enc_out, flash)
        aux = _aux_add(aux, ax)
        new_tail.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"groups": new_groups, "tail": new_tail}

    table = params.get("unembed", params["embed"])
    if logits == "hidden":
        out = x
    elif logits == "all":
        out = unembed(x, table, cfg.final_softcap)
    else:
        out = unembed(x[:, -1], table, cfg.final_softcap)
    return out, new_cache, aux


# ======================================================================
# losses


def train_loss(cfg: ModelConfig, params: Params, tokens, targets, mask,
               *, prefix_embeds=None, enc_embeds=None, remat: bool = True,
               flash=None, ce_chunk: int = 512, lb_coeff: float = 0.01):
    """Next-token CE with chunked unembedding (avoids (B,S,V) materialization)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, enc_embeds, flash=flash)
    hidden, _, aux = forward(cfg, params, tokens, cache=None, pos=None,
                             prefix_embeds=prefix_embeds, enc_out=enc_out,
                             logits="hidden", flash=flash, remat=remat)
    if cfg.input_mode == "mixed" and prefix_embeds is not None:
        hidden = hidden[:, prefix_embeds.shape[1]:]

    table = params.get("unembed", params["embed"])
    B, S, D = hidden.shape
    c = ce_chunk
    while S % c:
        c -= 1
    nchunk = S // c

    @jax.checkpoint
    def chunk_loss(idx):
        # rematted: the (B, c, V) logits would otherwise be stored as AD
        # residuals for every chunk — 67GB/chip at gemma2's 256k vocab.
        h = lax.dynamic_slice_in_dim(hidden, idx * c, c, axis=1)
        t = lax.dynamic_slice_in_dim(targets, idx * c, c, axis=1)
        m = lax.dynamic_slice_in_dim(mask, idx * c, c, axis=1)
        logits = unembed(h, table, cfg.final_softcap)        # (B,c,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * m
        return nll.sum(), m.sum()

    nlls, counts = lax.map(chunk_loss, jnp.arange(nchunk))
    loss = nlls.sum() / jnp.maximum(counts.sum(), 1.0)
    if cfg.is_moe:
        loss = loss + lb_coeff * aux["lb_loss"]
    return loss, aux
