"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked algorithm: intra-chunk quadratic (attention-like) term + inter-chunk
state recurrence carried by ``lax.scan`` — O(S·Q) compute, O(1) state. The
prefill-produced state (ssm_state, conv_state) is this architecture's
"sequence state" for PrefillShare sharing (DESIGN.md §4): prefill emits it,
decode consumes it, exactly like a KV cache but constant-size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init


def ssd_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_head_dim, cfg.ssm_state


def ssd_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in, nh, pdim, n = ssd_dims(cfg)
    conv_dim = d_in + 2 * n          # conv over concat(x, B, C), n_groups=1
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [z (d_in), x (d_in), B (n), C (n), dt (nh)]
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * n + nh), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((d_in,), dtype),  # gated RMSNorm pre out_proj
        "out_proj": dense_init(ks[2], (d_in, d), dtype=dtype),
    }


def init_ssd_cache(cfg, batch, dtype):
    d_in, nh, pdim, n = ssd_dims(cfg)
    conv_dim = d_in + 2 * n
    return {
        "ssm": jnp.zeros((batch, nh, pdim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def _causal_conv(x, w, b, conv_state):
    """x: (B,S,C), w: (W,C) depthwise. conv_state: (B,W-1,C) left context."""
    W = w.shape[0]
    xp = jnp.concatenate([conv_state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else conv_state
    return jax.nn.silu(out + b), new_state


def _segsum(a):
    """a: (..., L) -> cumulative sums a_i+..+a_j for j<i, (..., L, L) lower-tri."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]   # diff[i, j] = a_{j+1} + .. + a_i
    mask = jnp.tril(jnp.ones((L, L), bool))      # j <= i; diagonal = 0 decay

    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, A, B_, C_, init_state, chunk: int = 64):
    """Chunked SSD.

    x: (B,S,H,P) inputs; dt: (B,S,H) positive step sizes; A: (H,) negative;
    B_, C_: (B,S,N) (single group, broadcast over heads); init_state (B,H,P,N).
    Returns (y (B,S,H,P), final_state).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = chunk
    while S % Q:
        Q //= 2
    nc = S // Q

    a = dt * A[None, None, :]                       # (B,S,H) log-decay per step
    xc = x.reshape(Bb, nc, Q, H, P)
    ac = a.reshape(Bb, nc, Q, H).transpose(0, 3, 1, 2)  # (B,H,nc,Q)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B_.reshape(Bb, nc, Q, N)
    Cc = C_.reshape(Bb, nc, Q, N)

    a_cum = jnp.cumsum(ac, axis=-1)                 # (B,H,nc,Q)

    # intra-chunk (diagonal) term: attention-like with decay kernel
    L = jnp.exp(_segsum(ac))                        # (B,H,nc,Q,Q)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcls,bhcls,bcshp,bcsh->bclhp",
                        scores, L, xc, dtc)

    # per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bclh,bclhp->bchpn", Bc, decay_states, dtc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])            # (B,H,nc)

    def step(carry, xs):
        dec, st_chunk = xs                           # per-chunk
        new = carry * dec[..., None, None] + st_chunk
        return new, carry                            # emit state *entering* the chunk

    sts = jnp.moveaxis(states, 1, 0)                 # (nc,B,H,P,N)
    decs = jnp.moveaxis(chunk_decay, -1, 0)          # (nc,B,H)
    final_state, entry_states = lax.scan(step, init_state, (decs, sts))
    entry_states = jnp.moveaxis(entry_states, 0, 1)  # (B,nc,H,P,N)

    # contribution of the entering state to each position in the chunk
    state_decay = jnp.exp(a_cum)                     # (B,H,nc,Q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, entry_states, state_decay)

    y = (y_diag + y_off).reshape(Bb, S, H, P)
    return y, final_state


def ssd_apply(p, x, cfg, cache=None):
    """x: (B,S,D) -> (out, new_cache). Handles prefill, partial prefill, decode."""
    Bb, S, D = x.shape
    d_in, nh, pdim, n = ssd_dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, Bmat, Cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)

    conv_state = cache["conv"] if cache is not None else jnp.zeros(
        (Bb, cfg.conv_width - 1, d_in + 2 * n), x.dtype)
    conv_in = jnp.concatenate([xin, Bmat, Cmat], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    xin, Bmat, Cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xin.reshape(Bb, S, nh, pdim).astype(jnp.float32)
    init_state = cache["ssm"] if cache is not None else jnp.zeros(
        (Bb, nh, pdim, n), jnp.float32)

    if S == 1:
        # single-step recurrence (decode)
        da = jnp.exp(dt[:, 0, :] * A[None])                       # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0],
                         Bmat[:, 0].astype(jnp.float32))
        state = init_state * da[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cmat[:, 0].astype(jnp.float32), state)
        y = y[:, None]                                            # (B,1,H,P)
        final_state = state
    else:
        y, final_state = ssd_scan(xh, dt, A,
                                  Bmat.astype(jnp.float32),
                                  Cmat.astype(jnp.float32), init_state)

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, d_in).astype(x.dtype)

    # gated RMSNorm (mamba2 norm before out_proj)
    g = jax.nn.silu(z)
    yf = (y * g).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = (yf * lax.rsqrt(var + cfg.norm_eps) *
          (1.0 + p["norm_w"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", yn, p["out_proj"])
    new_cache = {"ssm": final_state, "conv": new_conv}
    return out, new_cache
