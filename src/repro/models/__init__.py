from repro.models.model import (encode, forward, init_cache, init_params,
                                train_loss)
