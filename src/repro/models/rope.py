"""Rotary position embeddings: full (llama/neox), partial (ChatGLM 2d-style), none."""
from __future__ import annotations

import jax.numpy as jnp


def _rotate(x, positions, theta: float):
    """Apply RoPE over the last dim of ``x`` (..., S, D) with ``positions`` (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x, positions, *, style: str = "full", theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) absolute token positions."""
    if style == "none":
        return x
    pos = positions[:, :, None]  # broadcast over heads
    xt = jnp.swapaxes(x, 1, 2)   # (B, H, S, D)
    pos = positions[:, None, :]  # (B, 1, S)
    if style == "full":
        out = _rotate(xt, pos, theta)
    elif style == "partial":
        # ChatGLM-style: rotary on the first half of head dims, pass-through rest.
        d = xt.shape[-1]
        rot, keep = xt[..., : d // 2], xt[..., d // 2 :]
        out = jnp.concatenate([_rotate(rot, pos, theta), keep], axis=-1)
    else:
        raise ValueError(f"unknown rope style {style!r}")
    return jnp.swapaxes(out, 1, 2)
