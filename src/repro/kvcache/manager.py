"""CacheManager: paged pool + prefix index + hit accounting for one worker.

In the BASELINE deployment each (model, prefill worker) pair owns a manager —
N models over the same session context hold N copies of every prefix page.
Under PrefillShare a single manager serves ALL decode models because every
page was produced by the shared frozen base model (cache schema compatible by
construction), which is exactly the paper's Eq. 8 -> Eq. 9 memory change.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, SSD, ModelConfig
from repro.kvcache.blocks import BlockPool, PoolExhausted
from repro.kvcache.radix import PrefixIndex


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Bytes of sequence state appended per token (KV for attn layers)."""
    per = 0
    for kind in cfg.layer_kinds():
        if kind == ATTN:
            per += 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        elif kind == LOCAL_ATTN:
            per += 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes  # window-capped overall
    if cfg.is_encdec:
        per += 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes  # decoder self-KV
    return per


def state_bytes_per_seq(cfg: ModelConfig, dtype_bytes: int = 4) -> int:
    """Constant-size per-sequence state (SSM/RG-LRU/conv states)."""
    total = 0
    d_in = cfg.ssm_expand * cfg.d_model
    for kind in cfg.layer_kinds():
        if kind == SSD:
            nh = d_in // cfg.ssm_head_dim
            total += nh * cfg.ssm_head_dim * cfg.ssm_state * dtype_bytes
            total += (cfg.conv_width - 1) * (d_in + 2 * cfg.ssm_state) * dtype_bytes
        elif kind == RGLRU:
            w = cfg.rglru_width or cfg.d_model
            total += w * dtype_bytes + (cfg.conv_width - 1) * w * dtype_bytes
    return total


@dataclass
class CacheStats:
    lookups: int = 0
    hit_tokens: int = 0
    total_tokens: int = 0
    relay_hit_tokens: int = 0    # subset of hit_tokens served from pages the
                                 # DECODE plane wrote (relay-published KV)

    @property
    def hit_ratio(self) -> float:
        return self.hit_tokens / self.total_tokens if self.total_tokens else 0.0

    @classmethod
    def merge(cls, stats) -> "CacheStats":
        """Roll per-worker hit accounting up into ONE fleet-wide surface.
        Engine (``engine.stats()``) and simulator (``summary()``) both report
        through this, so 'hit ratio' means the same number everywhere —
        including the relay share (decode-published pages), so fleet
        dashboards see cache occupancy and hits from BOTH provenances."""
        out = cls()
        for s in stats:
            out.lookups += s.lookups
            out.hit_tokens += s.hit_tokens
            out.total_tokens += s.total_tokens
            out.relay_hit_tokens += getattr(s, "relay_hit_tokens", 0)
        return out


@dataclass
class Allocation:
    cached_blocks: list
    new_blocks: list
    cached_tokens: int
    total_tokens: int

    @property
    def blocks(self):
        return self.cached_blocks + self.new_blocks


class CacheManager:
    def __init__(self, cfg: ModelConfig, num_blocks: int, block_size: int = 16,
                 *, pool: BlockPool | None = None, index=None):
        """``pool``: optionally share one physical BlockPool across several
        managers (one per prefill worker). Block ids then index the SAME
        physical page arrays (PagedKVPool), so pages allocated by any worker
        are directly addressable by every decode worker — the zero-copy
        handoff invariant.

        ``index``: optionally share one PrefixIndex across the managers on a
        shared pool (the ENGINE-GLOBAL radix tree: any prompt matches the
        longest prefix any worker published). The caller that created the
        shared index owns wiring its ``remove_block`` into the pool's
        eviction callbacks — exactly once, not once per manager. A manager
        constructed without ``index`` keeps a private tree over its own pool
        (the historical per-worker locality, still what the simulator's
        baseline mode measures) and registers the callback itself."""
        self.cfg = cfg
        if pool is None:
            pool = BlockPool(num_blocks, block_size)
        self.pool = pool
        if index is None:
            index = PrefixIndex(self.pool.block_size)
            self.pool.add_evict_callback(index.remove_block)
        self.index = index
        self.stats = CacheStats()
        self.bytes_per_block = kv_bytes_per_token(cfg) * self.pool.block_size

    # ------------------------------------------------------------------
    def acquire(self, tokens) -> Allocation:
        """Match the longest cached prefix, allocate pages for the rest.

        Raises PoolExhausted if the pool cannot host the request (admission
        control upstream should prevent this)."""
        bs = self.pool.block_size
        n_tok = len(tokens)
        cached_blocks, cached_tokens = self.index.match(tokens)
        # take refs before any allocation can evict them; ANY failure after
        # the ref (not just PoolExhausted) must give those refs back or the
        # cached pages leak as permanently active
        self.pool.ref(cached_blocks)
        try:
            self.pool.touch(cached_blocks)
            n_blocks_total = (n_tok + bs - 1) // bs
            need = n_blocks_total - len(cached_blocks)
            new_blocks = self.pool.alloc(need)
        except BaseException:
            self.pool.unref(cached_blocks)
            raise
        self.stats.lookups += 1
        self.stats.hit_tokens += cached_tokens
        self.stats.total_tokens += n_tok
        self.stats.relay_hit_tokens += self.index.relay_tokens(cached_blocks)
        return Allocation(cached_blocks, new_blocks, cached_tokens, n_tok)

    def begin(self, tokens) -> Allocation:
        """Chunk-granular admission: match + ref the cached prefix WITHOUT
        allocating tail pages (those arrive via ``extend`` as prefill chunks
        progress). Never raises PoolExhausted — taking refs on resident
        pages cannot run the pool dry, so a request can always be admitted
        and then backpressured at its first extend."""
        tokens = list(tokens)
        cached_blocks, cached_tokens = self.index.match(tokens)
        assert cached_tokens % self.pool.block_size == 0, \
            "prefix reuse is page-granular"
        self.pool.ref(cached_blocks)
        try:
            self.pool.touch(cached_blocks)
        except BaseException:
            self.pool.unref(cached_blocks)
            raise
        self.stats.lookups += 1
        self.stats.hit_tokens += cached_tokens
        self.stats.total_tokens += len(tokens)
        self.stats.relay_hit_tokens += self.index.relay_tokens(cached_blocks)
        return Allocation(cached_blocks, [], cached_tokens, len(tokens))

    def extend(self, alloc: Allocation, n_pages: int) -> list:
        """Grow an in-flight allocation by ``n_pages`` fresh pages (the pages
        one prefill chunk spills into). PoolExhausted propagates — the
        scheduler holds the chunk and retries once decode frees pages."""
        if n_pages <= 0:
            return []
        new = self.pool.alloc(n_pages)
        try:
            alloc.new_blocks.extend(new)
        except BaseException:
            self.pool.drop(new)
            raise
        return new

    def commit(self, tokens, alloc: Allocation) -> None:
        """After prefill fills the new pages, publish them for prefix reuse."""
        self.index.insert(tokens, alloc.blocks)

    def release(self, alloc: Allocation) -> None:
        self.pool.unref(alloc.blocks)

    def abandon(self, alloc: Allocation) -> None:
        """Reclaim an in-flight allocation that will NEVER be committed (an
        aborted request's chunk-granular pages). The cached prefix pages it
        referenced return to the LRU cache — they hold valid published KV
        other requests can still hit — while the tail pages acquired via
        ``acquire``/``extend`` are hard-freed: their KV is partially written
        and was never published to the prefix index, so retaining them could
        only alias garbage. Free-page count returns exactly to the
        pre-request baseline."""
        self.pool.unref(alloc.cached_blocks)
        self.pool.drop(alloc.new_blocks)

    def record_hit(self, n_tokens: int) -> None:
        """Account a request served ENTIRELY from resident pages without a
        fresh allocation (e.g. a sibling fan-out reusing a live session's
        block table). Keeps engine hit ratios on this manager's books."""
        self.stats.lookups += 1
        self.stats.hit_tokens += n_tokens
        self.stats.total_tokens += n_tokens

    # ------------------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        return self.pool.active_count * self.bytes_per_block

    @property
    def capacity_bytes(self) -> int:
        return self.pool.num_blocks * self.bytes_per_block
