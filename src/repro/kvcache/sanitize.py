"""KV-pool sanitizer: the runtime half of the analysis story.

The static rules (repro.analysis) prove discipline at call sites; this
module checks the STATE those disciplines are supposed to preserve, at every
scheduler step boundary (``LocalDisaggEngine(..., sanitize=True)``):

- pool conservation: every page id is in exactly one of FREE / CACHED /
  ACTIVE / SWAPPED, and the populations sum to the pool capacity;
- swap-tier cross-check: the SWAPPED population equals exactly the pages
  the preemption subsystem's swap records claim are still device-resident
  (a leaked swapped page is diagnosed naming the swap tier as holder
  class);
- refcount cross-check: for every page, the pool's refcount equals the
  number of holders the engine's own structures claim — prefill-session
  allocations, in-flight chunked requests (their allocation, or the sibling
  pin), and decode sequences' shared/private block tables;
- sentinel hygiene: page 0 (the never-allocated padding sentinel) appears
  in no live block table;
- radix↔pool consistency: every block the prefix index can serve a match
  from is resident (active or LRU-cached), never free — including
  RELAY-PUBLISHED pages (decode-written KV adopted into the tree at
  sequence finish), which are a first-class population in the census: they
  must sit at refcount 0 (CACHED) unless a live holder (session allocation,
  in-flight request, decode sequence) explicitly references them, and a
  leaked ACTIVE relay page is diagnosed by name;
- donation poisoning: ``SanitizedKVPool`` replaces the leaves of every
  previously handed-out ``decode_state``/``make_decode_cache`` pytree with
  ``_PoisonedBuffer`` the moment the paired absorb lands — a read through a
  stale handle (which on TPU would be use-after-donation of a dead buffer)
  raises ``SanitizerError`` immediately, instead of silently reading valid
  memory on backends where donation is a no-op.

Checks never mutate pool or engine state and run entirely on the host, so a
``sanitize=True`` run is token-bit-identical to ``sanitize=False``
(asserted in tests/test_sanitizer.py).
"""
from __future__ import annotations

from repro.kvcache.paged import PagedKVPool


class SanitizerError(AssertionError):
    """A serving invariant was violated (diagnostics in the message)."""


def _fail(msg: str):
    raise SanitizerError(msg)


# ----------------------------------------------------------------------
# standalone checkers (usable from property tests without an engine)
# ----------------------------------------------------------------------

def check_pool(pool) -> None:
    """Raising version of ``BlockPool.check_invariants`` with precise
    diagnostics: every page in exactly one state, populations conserved."""
    free = set(pool._free)
    cached = set(pool._cached)
    swapped = set(getattr(pool, "_swapped", ()))
    if len(free) != len(pool._free):
        _fail(f"pool free list holds duplicate ids: {sorted(pool._free)}")
    both = free & cached
    if both:
        _fail(f"pages {sorted(both)} are simultaneously FREE and CACHED")
    overlap = swapped & (free | cached)
    if overlap:
        _fail(f"pages {sorted(overlap)} are SWAPPED but also in the "
              f"free/cached population — swap-out must remove the page "
              f"from every other state")
    if pool.SENTINEL in free or pool.SENTINEL in cached:
        _fail("sentinel page 0 entered the free/cached population — "
              "something allocated or released the padding page")
    if pool.SENTINEL in swapped:
        _fail("sentinel page 0 entered the SWAPPED population — the "
              "padding page holds no KV to swap")
    active = 0
    for bid in range(1, pool.num_blocks + 1):
        rc = pool._refcount[bid]
        if rc < 0:
            _fail(f"page {bid} refcount is negative ({rc}): over-released")
        in_free, in_cached = bid in free, bid in cached
        in_swapped = bid in swapped
        if rc > 0:
            if in_free or in_cached or in_swapped:
                state = ("free" if in_free
                         else "cached" if in_cached else "swapped")
                _fail(f"page {bid} is ACTIVE (refcount {rc}) but also in "
                      f"the {state} population")
            active += 1
        elif not (in_free or in_cached or in_swapped):
            _fail(f"page {bid} is in no state: refcount 0, not free, "
                  f"not cached, not swapped (leaked out of the pool)")
        elif in_cached and rc != 0:
            _fail(f"CACHED page {bid} has refcount {rc} (must be 0)")
    if len(free) + len(cached) + len(swapped) + active != pool.num_blocks:
        _fail(f"pool conservation broken: {len(free)} free + {len(cached)} "
              f"cached + {len(swapped)} swapped + {active} active != "
              f"{pool.num_blocks} total")
    if pool._refcount[pool.SENTINEL] != 0:
        _fail(f"sentinel page 0 has refcount "
              f"{pool._refcount[pool.SENTINEL]} — it must never be held")


def check_index(index, pool=None) -> None:
    """Radix-tree structural invariants, plus (with ``pool``) residency:
    every block the index can serve a match from must be active or cached,
    never free — a free page's KV is about to be overwritten."""
    if index is None or not hasattr(index, "_by_block"):
        return                       # NullPrefixIndex / disabled
    for bid, node in index._by_block.items():
        if node.block_id != bid:
            _fail(f"index entry for block {bid} points at node carrying "
                  f"block {node.block_id}")
        if node.parent is None:
            _fail(f"index node for block {bid} has no parent (detached "
                  f"from the tree but still matchable)")
        if node.parent.children.get(node.key) is not node:
            _fail(f"index node for block {bid} is not linked from its "
                  f"parent — match() and _by_block disagree")
        p = node.parent
        while p is not index.root:
            if p.block_id not in index._by_block:
                _fail(f"block {bid} has unregistered ancestor block "
                      f"{p.block_id}: an orphan chain survived eviction")
            p = p.parent
        if pool is not None:
            if bid == pool.SENTINEL:
                _fail("prefix index holds the sentinel page 0")
            if bid in getattr(pool, "_swapped", ()):
                _fail(f"prefix index can serve block {bid} but the pool "
                      f"has it SWAPPED — its KV lives in the host swap "
                      f"tier and the device row is revocable")
            if pool._refcount[bid] == 0 and bid not in pool._cached:
                _fail(f"prefix index can serve block {bid} but the pool "
                      f"has it FREE — matches would alias recycled KV")


# ----------------------------------------------------------------------
# donation poisoning
# ----------------------------------------------------------------------

class _PoisonedBuffer:
    """Stand-in for a donated page buffer: any read raises. Emulates, on
    every backend, the TPU reality that a donated buffer is dead after the
    jitted step it was donated into."""

    __slots__ = ("_why",)

    def __init__(self, why: str):
        object.__setattr__(self, "_why", why)

    def _trap(self, op: str):
        raise SanitizerError(
            f"use-after-donation: {op} on a page buffer that was donated "
            f"into {object.__getattribute__(self, '_why')} — re-fetch state "
            f"via decode_state()/make_decode_cache() after every absorb")

    def __getattr__(self, name):
        self._trap(f"attribute access .{name}")

    def __getitem__(self, item):
        self._trap(f"indexing [{item!r}]")

    def __iter__(self):
        self._trap("iteration")

    def __len__(self):
        self._trap("len()")

    def __bool__(self):
        self._trap("bool()")

    def __array__(self, *a, **k):
        self._trap("conversion to array")

    def __add__(self, other):
        self._trap("arithmetic")

    __radd__ = __mul__ = __rmul__ = __sub__ = __rsub__ = __add__

    def __repr__(self):
        return "<poisoned donated buffer>"


def _poison_tree(obj, why: str) -> None:
    """Replace every array leaf in a handed-out state pytree with a trap,
    mutating the containers in place (the caller's references see it)."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            if isinstance(v, (dict, list)):
                _poison_tree(v, why)
            elif not isinstance(v, _PoisonedBuffer):
                obj[k] = _PoisonedBuffer(why)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            if isinstance(v, (dict, list)):
                _poison_tree(v, why)
            elif not isinstance(v, _PoisonedBuffer):
                obj[i] = _PoisonedBuffer(why)


class SanitizedKVPool(PagedKVPool):
    """PagedKVPool that tracks handed-out decode-state pytrees and poisons
    them the moment the paired absorb retires them. The arrays returned are
    the same objects the base class returns, so token streams are
    bit-identical — only reads through STALE handles change behavior."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._outstanding: list = []     # handed-out state/cache pytrees

    def _retire(self, why: str) -> None:
        for tree in self._outstanding:
            _poison_tree(tree, why)
        self._outstanding.clear()

    def decode_state(self):
        state = super().decode_state()
        self._outstanding.append(state)
        return state

    def absorb_decode_state(self, state) -> None:
        # the absorbed tree is the step's LIVE return value — never poison
        # it, even if a caller round-trips the handed-out dict unchanged
        self._outstanding = [t for t in self._outstanding if t is not state]
        self._retire("a donated decode step (absorb_decode_state)")
        super().absorb_decode_state(state)

    def make_decode_cache(self, block_tables, state=None):
        cache = super().make_decode_cache(block_tables, state)
        self._outstanding.append(cache)
        return cache

    def absorb_decode_cache(self, new_cache) -> None:
        self._outstanding = [t for t in self._outstanding
                             if t is not new_cache]
        self._retire("a donated decode step (absorb_decode_cache)")
        super().absorb_decode_cache(new_cache)

    def copy_page(self, src: int, dst: int) -> None:
        # the CoW clone donates the whole pool pytree on TPU: any state
        # handed out before it is dead afterwards too
        self._retire("copy_page's donated pool update")
        super().copy_page(src, dst)

    def pool_state(self):
        state = super().pool_state()
        self._outstanding.append(state)
        return state

    def set_pool_state(self, new) -> None:
        # the swap tier's scatter-on-resume donates the whole pool pytree on
        # TPU (like copy_page); `new` is the update's live return value
        self._outstanding = [t for t in self._outstanding if t is not new]
        self._retire("a donated whole-pool update (set_pool_state)")
        super().set_pool_state(new)


# ----------------------------------------------------------------------
# engine-level step-boundary checker
# ----------------------------------------------------------------------

class PoolSanitizer:
    """Cross-checks the pool's refcounts against the holders the engine's
    own structures claim, at every scheduler step boundary."""

    def __init__(self, engine):
        self.engine = engine
        self.checks = 0          # step boundaries validated (test hook)

    # -- holder census --------------------------------------------------
    def _relay_published(self) -> set[int]:
        """Page ids the radix tree serves from RELAY provenance (decode-
        written KV published at sequence finish). Relay publication adds a
        page LIFECYCLE, not a holder class: a published page is unref'd to
        CACHED (refcount 0) in the same ``_finish`` that adopted it, so the
        census expects relay pages to be held only by the ordinary holders
        below (a later request's cached-prefix ref, a session allocation).
        The set exists so a violation NAMES the relay page as such."""
        idx = self.engine.prefix_index
        if idx is None or not hasattr(idx, "_by_block"):
            return set()
        return {bid for bid, nd in idx._by_block.items()
                if getattr(nd, "provenance", "prefill") == "relay"}

    def _expected_refcounts(self) -> dict[int, list[str]]:
        """page id -> list of holder descriptions (one entry per expected
        reference), from prefill sessions, in-flight chunked requests, and
        active decode sequences. Relay-published pages appear here exactly
        when one of those holders references them (e.g. a request whose
        cached prefix includes relayed pages) — publication itself leaves
        them CACHED at refcount 0 (see ``_relay_published``)."""
        eng = self.engine
        holders: dict[int, list[str]] = {}

        def hold(bid: int, who: str):
            holders.setdefault(bid, []).append(who)

        seen_allocs: set[int] = set()
        for w in eng.prefill_workers:
            for sid, sc in getattr(w, "sessions", {}).items():
                alloc = getattr(sc, "alloc", None)
                if alloc is None or id(alloc) in seen_allocs:
                    continue
                seen_allocs.add(id(alloc))
                for bid in alloc.blocks:
                    hold(bid, f"session {sid} (worker {w.wid})")
        sched = eng.scheduler
        for r in sched.prefilling:
            if r.sibling_bt is not None:
                for bid in r.sibling_bt:
                    hold(bid, f"request {r.rid} sibling pin")
            elif r.alloc is not None and id(r.alloc) not in seen_allocs:
                # after _commit_request the SAME Allocation object lives in
                # the session (counted above) — only count it once
                seen_allocs.add(id(r.alloc))
                for bid in r.alloc.blocks:
                    hold(bid, f"request {r.rid} in-flight allocation")
        for s in sched.active:
            for bid in s.shared_blocks:
                hold(bid, f"decode seq rid={s.rid} shared")
            for bid in s.private_blocks:
                hold(bid, f"decode seq rid={s.rid} private")
        swap = getattr(eng, "swap", None)
        if swap is not None:
            # a parked (swapped-out) sequence keeps its cached-prefix refs:
            # only its PRIVATE pages moved to the swap tier (refcount 0,
            # SWAPPED population — censused in check_step, not here)
            for rid, rec in swap.records.items():
                for bid in rec.seq.shared_blocks:
                    hold(bid, f"swapped seq rid={rid} shared (swap tier)")
        return holders

    # -- checks ----------------------------------------------------------
    def _live_tables(self):
        eng = self.engine
        for w in eng.prefill_workers:
            for sid, sc in getattr(w, "sessions", {}).items():
                bt = getattr(sc, "block_table", None)
                if bt is not None:
                    yield f"session {sid} (worker {w.wid})", bt
        for r in eng.scheduler.prefilling:
            if r.sibling_bt is not None:
                yield f"request {r.rid} sibling table", r.sibling_bt
            elif r.block_table:
                yield f"request {r.rid} prefill table", r.block_table
        for s in eng.scheduler.active:
            yield f"decode seq rid={s.rid}", s.block_table

    def _check_swap_tier(self, pool) -> None:
        """The SWAPPED population must be exactly the pages the swap tier's
        records claim are still device-resident: a SWAPPED page with no
        owning record leaked (its host copy is unreachable), and a record
        claiming residency the pool disavows would scatter onto a row that
        belongs to someone else."""
        swap = getattr(self.engine, "swap", None)
        claimed: dict[int, int] = {}            # bid -> rid
        if swap is not None:
            for rid, rec in swap.records.items():
                for bid in rec.resident:
                    if bid in claimed:
                        _fail(f"page {bid} is claimed swap-resident by BOTH "
                              f"rid={claimed[bid]} and rid={rid}")
                    claimed[bid] = rid
        for bid in sorted(getattr(pool, "_swapped", ())):
            if bid not in claimed:
                _fail(f"page {bid} is SWAPPED in the pool but NO swap "
                      f"record owns its host copy — holder: swap tier "
                      f"(preempted sequence KV parked in host memory); a "
                      f"swap_out without a matching HostSwapPool entry "
                      f"leaks the page")
        for bid, rid in sorted(claimed.items()):
            if bid not in pool._swapped:
                _fail(f"swap record rid={rid} claims page {bid} is still "
                      f"device-resident but the pool does not have it "
                      f"SWAPPED — a scatter-on-resume would overwrite a "
                      f"row owned by someone else")

    def check_step(self) -> None:
        eng = self.engine
        pool = eng.block_pool
        check_pool(pool)
        check_index(eng.prefix_index, pool)
        for who, bt in self._live_tables():
            if pool.SENTINEL in bt:
                _fail(f"sentinel page 0 appears in the live block table of "
                      f"{who}: {bt} — padding leaked into ownership")
        self._check_swap_tier(pool)
        holders = self._expected_refcounts()
        relay = self._relay_published()
        for bid, who in sorted(holders.items()):
            rc = pool._refcount[bid]
            if rc != len(who):
                tag = (" [relay-published page]" if bid in relay else "")
                _fail(f"refcount mismatch on page {bid}{tag}: pool says "
                      f"{rc}, engine structures hold {len(who)} "
                      f"reference(s) ({'; '.join(who)})")
        for bid in range(1, pool.num_blocks + 1):
            rc = pool._refcount[bid]
            if rc > 0 and bid not in holders:
                if bid in relay:
                    _fail(f"page {bid} is ACTIVE (refcount {rc}) but NO "
                          f"engine structure holds it — holder: relay "
                          f"publication (decode-written page adopted by the "
                          f"radix tree at finish); _finish/_relay_publish "
                          f"must unref adopted pages to CACHED, so an "
                          f"ACTIVE holderless relay page is a leaked "
                          f"reference")
                _fail(f"page {bid} is ACTIVE (refcount {rc}) but NO engine "
                      f"structure holds it — a leaked reference (missing "
                      f"unref/drop on some exit path)")
        self.checks += 1
