from repro.kvcache.blocks import BlockPool, PoolExhausted
from repro.kvcache.handoff import HandoffChannel, HandoffPlan, SchemaMismatch
from repro.kvcache.manager import (Allocation, CacheManager, CacheStats,
                                   kv_bytes_per_token, state_bytes_per_seq)
from repro.kvcache.paged import PagedKVPool
from repro.kvcache.radix import NullPrefixIndex, PrefixIndex
from repro.kvcache.sanitize import (PoolSanitizer, SanitizedKVPool,
                                    SanitizerError, check_index, check_pool)
