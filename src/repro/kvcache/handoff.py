"""Cache handoff: prefill worker -> decode worker transfer (paper §3.3 step 3).

On the paper's GPU prototype this is vLLM's KV connector (NVLink/PCIe, with
CPU staging under pressure — Appendix B.2). On TPU the handoff is a
device-to-device copy over ICI links; the simulator prices it at
``bytes / (links × link_bw)`` and models the Appendix-B.2 staging penalty when
the decode side's resident KV exceeds its HBM budget.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.prefillshare import CacheSchema
from repro.kvcache.manager import kv_bytes_per_token, state_bytes_per_seq


class SchemaMismatch(Exception):
    """Receiving decoder was not trained against this base prefill module."""


@dataclass
class HandoffPlan:
    bytes: int
    seconds: float
    staged: bool          # True if CPU-staging penalty applied (B.2 behavior)


class HandoffChannel:
    """Costed transfer channel between a prefill and a decode worker.

    Two pricing paths:
      - ``plan`` (dense / cross-mesh): ANALYTIC — bytes over link bandwidth
        with the Appendix-B.2 staging penalty. The simulator's model; stays
        analytic because the simulated transfer never actually runs.
      - ``plan_paged`` (zero-copy paged handoff): MEASURED — the engine times
        every real handoff (refcounting + tail-page CoW, ``observe_paged``)
        and the plan reports the EWMA of those wall times. Before the first
        observation the estimate is 0.0 (honest "no data"), never a made-up
        wire constant: an in-process pointer handoff priced at link bandwidth
        was fiction, and the router consumed it."""

    #: EWMA weight for measured handoff samples (matches ThroughputEWMA's
    #: smoothing horizon: a few dozen samples to converge)
    MEASURE_ALPHA = 0.2

    def __init__(self, cfg: ModelConfig, *, link_gbps: float = 50.0,
                 n_links: int = 1, staging_penalty: float = 4.0):
        self.cfg = cfg
        self.bw = link_gbps * 1e9 * n_links
        self.staging_penalty = staging_penalty
        self.measured_bytes = 0.0     # EWMA of observed paged-handoff bytes
        self.measured_s = 0.0         # EWMA of observed paged-handoff seconds
        self.samples = 0

    def plan(self, n_tokens: int, *, decode_hbm_free_bytes: int | None = None
             ) -> HandoffPlan:
        b = kv_bytes_per_token(self.cfg) * n_tokens + state_bytes_per_seq(self.cfg)
        staged = (decode_hbm_free_bytes is not None
                  and b > max(decode_hbm_free_bytes, 0))
        secs = b / self.bw * (self.staging_penalty if staged else 1.0)
        return HandoffPlan(bytes=b, seconds=secs, staged=staged)

    def observe_paged(self, nbytes: int, seconds: float) -> None:
        """Feed one MEASURED zero-copy handoff (metadata bytes + wall time
        of the refcount/CoW work) into the channel's estimate. The engine
        calls this at every prefill->decode handoff."""
        self.samples += 1
        if self.samples == 1:
            self.measured_bytes = float(nbytes)
            self.measured_s = float(seconds)
        else:
            a = self.MEASURE_ALPHA
            self.measured_bytes += a * (nbytes - self.measured_bytes)
            self.measured_s += a * (seconds - self.measured_s)

    def estimate_paged_s(self) -> float:
        """Expected wall time of one zero-copy handoff, from measurements
        (0.0 until the first handoff has been observed)."""
        return self.measured_s

    def plan_paged(self, n_pages: int) -> HandoffPlan:
        """Zero-copy handoff over the shared paged pool: the wire carries
        ONLY the block-table reference (int32 page ids + length/schema
        header); the KV pages themselves never move — the decode worker
        reads them in place and refcounts keep them alive. ``seconds`` is
        the measured per-handoff EWMA (see ``observe_paged``), not a
        bandwidth fiction."""
        b = 4 * n_pages + 16
        return HandoffPlan(bytes=b, seconds=self.measured_s, staged=False)

    @staticmethod
    def check(producer: CacheSchema, consumer_expected: CacheSchema) -> None:
        if not producer.compatible_with(consumer_expected):
            raise SchemaMismatch(
                f"cache from base {producer.base_model_id} cannot feed a "
                f"decoder trained against {consumer_expected.base_model_id}")


def transfer_cache(cache, device=None):
    """Real-engine path: move a cache pytree (used by the small-scale engine
    integration tests; a single-host copy here, jax.device_put cross-device
    on multi-chip runtimes)."""
    import jax
    if device is None:
        return jax.tree.map(lambda x: x + 0, cache)   # materialize a copy
    return jax.device_put(cache, device)


# ----------------------------------------------------------------------
# Beyond-paper: int8 handoff compression.
# The shared cache crosses the prefill->decode link on EVERY model switch;
# symmetric per-channel int8 halves the wire bytes (vs bf16). Decode-side
# dequantizes into its resident cache. Quality validated in
# tests/test_handoff_quant.py (cache-conditioned decode is tolerant to the
# quantization noise: logits drift < 1e-2 on the tiny model).


def quantize_cache(cache):
    """KV leaves (float, ndim>=3) -> {'q': int8, 'scale': f32 per-channel}."""
    import jax
    import jax.numpy as jnp

    def q(x):
        if not hasattr(x, "dtype") or not jnp.issubdtype(x.dtype, jnp.floating) \
                or x.ndim < 3:
            return x
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-2, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        qv = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return {"q": qv.astype(jnp.int8), "scale": scale.astype(jnp.float32),
                "dtype": str(x.dtype)}

    return jax.tree.map(q, cache)


def dequantize_cache(qcache):
    import jax
    import jax.numpy as jnp

    def dq(x):
        if isinstance(x, dict) and set(x) == {"q", "scale", "dtype"}:
            return (x["q"].astype(jnp.float32) * x["scale"]).astype(x["dtype"])
        return x

    return jax.tree.map(dq, qcache,
                        is_leaf=lambda x: isinstance(x, dict)
                        and set(x) == {"q", "scale", "dtype"})


def quantized_bytes(cache) -> int:
    """Wire bytes of the int8-compressed cache (payload + scales)."""
    import jax
    import jax.numpy as jnp
    total = 0
    for leaf in jax.tree.leaves(cache):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating) \
                and leaf.ndim >= 3:
            total += leaf.size                        # int8 payload
            total += (leaf.size // leaf.shape[-2]) * 4  # scales
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total
