"""Paged KV block pool: refcounted physical pages with LRU reuse.

The control plane of PagedAttention adapted for the shared-prefill setting:
physical pages hold KV produced by the *base* model, so the same page can be
referenced by requests headed to different decode models. Pages move through
states: FREE -> ACTIVE (refcount > 0) -> CACHED (refcount 0, retained for
prefix reuse, LRU-evictable) -> FREE.

A fourth state backs oversubscription (serving/preempt.py): SWAPPED — the
page's KV lives in a host-memory swap tier, the device row is reclaimable.
``swap_out`` moves a sole-holder ACTIVE page to SWAPPED; ``alloc`` may
revoke a SWAPPED page (its host copy stays valid, so the swap tier is
as-good-as-free capacity — revocation fires a callback so the tier knows
the device row is gone); ``reclaim_swapped`` resumes a still-resident page
in place with zero data movement; ``discard_swapped`` frees on abort.

Page id 0 is the PADDING SENTINEL: it is never allocated, so every ragged
block table zero-padded to a common width (batched decode steps, chunked
prefill, the fused multi-model plane's fake batch rows) aliases a page that
holds no live KV by construction. Usable ids are 1..num_blocks; ``num_blocks``
remains the usable capacity.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass


class PoolExhausted(Exception):
    pass


@dataclass
class PoolStats:
    allocs: int = 0
    evictions: int = 0
    peak_used: int = 0


class BlockPool:
    #: page id reserved as the never-allocated block-table padding sentinel
    SENTINEL = 0

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks          # usable capacity: ids 1..num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks, 0, -1))
        self._refcount = [0] * (num_blocks + 1)
        self._cached = OrderedDict()          # block_id -> None, LRU order
        self._swapped = set()                 # KV in the host swap tier
        self._evict_cbs = []                  # notify indexes on eviction
        self._swap_reclaim_cbs = []           # notify swap tier on revocation
        self.stats = PoolStats()

    # ------------------------------------------------------------------
    def add_evict_callback(self, cb):
        """Register an additional eviction listener.

        A pool shared by several CacheManagers must notify EVERY registered
        index when a physical page is reclaimed — any of them may hold a
        node for it. With the engine-global radix tree there is one shared
        index (registered once, by the engine); per-manager private indexes
        (simulator baseline mode) each register their own. Either way a
        callback fires BEFORE the page re-enters the free list, so no index
        can serve a match for a page whose KV is about to be overwritten."""
        self._evict_cbs.append(cb)

    def add_swap_reclaim_callback(self, cb):
        """Register a listener fired when ``alloc`` revokes a SWAPPED page.

        The swap tier (kvcache/swap.py) registers here: a revoked page's
        device row now belongs to a new owner, so the tier must mark the
        victim's page non-resident and restore it from the host copy on
        resume. The callback fires BEFORE the page is handed out."""
        self._swap_reclaim_cbs.append(cb)

    @property
    def free_count(self) -> int:
        # SWAPPED pages count as free capacity: their KV is safe on the host,
        # so the device rows are reclaimable on demand (revocation callback).
        return len(self._free) + len(self._swapped) + len(self._cached)

    @property
    def cached_count(self) -> int:
        """Pages retained at refcount 0 for prefix reuse (LRU-evictable)."""
        return len(self._cached)

    @property
    def swapped_count(self) -> int:
        """Pages whose KV lives in the host swap tier (device row reclaimable)."""
        return len(self._swapped)

    @property
    def active_count(self) -> int:
        return self.num_blocks - self.free_count

    def alloc(self, n: int) -> list[int]:
        """Allocate n fresh blocks (refcount=1), evicting LRU cached blocks
        if the free list runs dry."""
        if n > self.free_count:
            raise PoolExhausted(f"need {n}, have {self.free_count}")
        out = []
        for _ in range(n):
            if not self._free:
                if self._swapped:
                    # revoke a swapped page's device row: its KV is safe in
                    # the host tier, the CACHED prefix KV would be lost —
                    # so swapped rows are reclaimed before LRU eviction
                    bid = self._swapped.pop()
                    for cb in self._swap_reclaim_cbs:
                        cb(bid)
                else:
                    bid, _ = self._cached.popitem(last=False)  # LRU
                    self.stats.evictions += 1
                    for cb in self._evict_cbs:
                        cb(bid)
                self._free.append(bid)
            bid = self._free.pop()
            self._refcount[bid] = 1
            out.append(bid)
        self.stats.allocs += n
        self.stats.peak_used = max(self.stats.peak_used, self.active_count)
        return out

    def ref(self, block_ids) -> None:
        """Take a reference on existing blocks (prefix-cache hit)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            if bid in self._swapped:
                raise ValueError(
                    f"block {bid} is SWAPPED (KV in the host tier); "
                    f"reclaim_swapped it, do not ref")
            if self._refcount[bid] == 0:
                if bid not in self._cached:
                    raise ValueError(f"block {bid} is free, cannot ref")
                del self._cached[bid]
            self._refcount[bid] += 1

    def unref(self, block_ids) -> None:
        """Drop a reference; refcount-0 blocks become CACHED (LRU-retained)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            rc = self._refcount[bid]
            if rc <= 0:
                raise ValueError(f"block {bid} not active")
            self._refcount[bid] = rc - 1
            if rc == 1:
                self._cached[bid] = None
                self._cached.move_to_end(bid)

    def touch(self, block_ids) -> None:
        """Refresh LRU position of cached blocks (on prefix hit)."""
        for bid in block_ids:
            if bid in self._cached:
                self._cached.move_to_end(bid)

    def drop(self, block_ids) -> None:
        """Hard-free blocks (invalidated, e.g. schema mismatch)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            if bid in self._swapped:
                raise ValueError(
                    f"block {bid} is SWAPPED; use discard_swapped")
            if bid in self._cached:
                del self._cached[bid]
            self._refcount[bid] = 0
            self._free.append(bid)

    # ------------------------------------------------------------------
    # swap tier (oversubscription: serving/preempt.py owns the lifecycle)
    # ------------------------------------------------------------------
    def swap_out(self, block_ids) -> None:
        """ACTIVE -> SWAPPED: the caller has copied these pages' KV to the
        host tier and relinquishes the device rows. Only sole-holder pages
        may swap (refcount must be exactly 1 — a shared page's other holders
        would read a revoked row)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            rc = self._refcount[bid]
            if rc != 1:
                raise ValueError(
                    f"block {bid} has refcount {rc}, only sole-holder "
                    f"(refcount 1) pages may swap out")
            self._refcount[bid] = 0
            self._swapped.add(bid)

    def reclaim_swapped(self, block_ids) -> None:
        """SWAPPED -> ACTIVE in place: the device row was never revoked, so
        the resuming sequence reattaches with zero data movement."""
        for bid in block_ids:
            if bid not in self._swapped:
                raise ValueError(f"block {bid} is not swapped")
            self._swapped.discard(bid)
            self._refcount[bid] = 1

    def discard_swapped(self, block_ids) -> None:
        """SWAPPED -> FREE: the parked sequence was aborted, its host copy
        is being dropped and the device rows return to the pool."""
        for bid in block_ids:
            if bid not in self._swapped:
                raise ValueError(f"block {bid} is not swapped")
            self._swapped.discard(bid)
            self._free.append(bid)

    def refcount(self, bid: int) -> int:
        return self._refcount[bid]

    def check_invariants(self) -> None:
        """Property-test hook: every block is in exactly one state."""
        free = set(self._free)
        cached = set(self._cached)
        swapped = set(self._swapped)
        assert not (free & cached), "block both free and cached"
        assert not (swapped & (free | cached)), \
            "swapped block also free or cached"
        assert self.SENTINEL not in free and self.SENTINEL not in cached \
            and self.SENTINEL not in swapped, "sentinel page 0 entered the pool"
        assert self._refcount[self.SENTINEL] == 0, "sentinel page 0 is live"
        for bid in range(1, self.num_blocks + 1):
            rc = self._refcount[bid]
            if bid in free:
                assert rc == 0, f"free block {bid} has refcount {rc}"
            elif bid in cached:
                assert rc == 0, f"cached block {bid} has refcount {rc}"
            elif bid in swapped:
                assert rc == 0, f"swapped block {bid} has refcount {rc}"
            else:
                assert rc > 0, f"active block {bid} has refcount {rc}"
        assert len(free) + len(cached) + len(swapped) + sum(
            1 for r in self._refcount if r > 0) == self.num_blocks
