"""Paged KV block pool: refcounted physical pages with LRU reuse.

The control plane of PagedAttention adapted for the shared-prefill setting:
physical pages hold KV produced by the *base* model, so the same page can be
referenced by requests headed to different decode models. Pages move through
states: FREE -> ACTIVE (refcount > 0) -> CACHED (refcount 0, retained for
prefix reuse, LRU-evictable) -> FREE.

Page id 0 is the PADDING SENTINEL: it is never allocated, so every ragged
block table zero-padded to a common width (batched decode steps, chunked
prefill, the fused multi-model plane's fake batch rows) aliases a page that
holds no live KV by construction. Usable ids are 1..num_blocks; ``num_blocks``
remains the usable capacity.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass


class PoolExhausted(Exception):
    pass


@dataclass
class PoolStats:
    allocs: int = 0
    evictions: int = 0
    peak_used: int = 0


class BlockPool:
    #: page id reserved as the never-allocated block-table padding sentinel
    SENTINEL = 0

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks          # usable capacity: ids 1..num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks, 0, -1))
        self._refcount = [0] * (num_blocks + 1)
        self._cached = OrderedDict()          # block_id -> None, LRU order
        self._evict_cbs = []                  # notify indexes on eviction
        self.stats = PoolStats()

    # ------------------------------------------------------------------
    def add_evict_callback(self, cb):
        """Register an additional eviction listener.

        A pool shared by several CacheManagers must notify EVERY registered
        index when a physical page is reclaimed — any of them may hold a
        node for it. With the engine-global radix tree there is one shared
        index (registered once, by the engine); per-manager private indexes
        (simulator baseline mode) each register their own. Either way a
        callback fires BEFORE the page re-enters the free list, so no index
        can serve a match for a page whose KV is about to be overwritten."""
        self._evict_cbs.append(cb)

    @property
    def free_count(self) -> int:
        return len(self._free) + len(self._cached)

    @property
    def cached_count(self) -> int:
        """Pages retained at refcount 0 for prefix reuse (LRU-evictable)."""
        return len(self._cached)

    @property
    def active_count(self) -> int:
        return self.num_blocks - self.free_count

    def alloc(self, n: int) -> list[int]:
        """Allocate n fresh blocks (refcount=1), evicting LRU cached blocks
        if the free list runs dry."""
        if n > self.free_count:
            raise PoolExhausted(f"need {n}, have {self.free_count}")
        out = []
        for _ in range(n):
            if not self._free:
                bid, _ = self._cached.popitem(last=False)  # LRU
                self.stats.evictions += 1
                for cb in self._evict_cbs:
                    cb(bid)
                self._free.append(bid)
            bid = self._free.pop()
            self._refcount[bid] = 1
            out.append(bid)
        self.stats.allocs += n
        self.stats.peak_used = max(self.stats.peak_used, self.active_count)
        return out

    def ref(self, block_ids) -> None:
        """Take a reference on existing blocks (prefix-cache hit)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            if self._refcount[bid] == 0:
                if bid not in self._cached:
                    raise ValueError(f"block {bid} is free, cannot ref")
                del self._cached[bid]
            self._refcount[bid] += 1

    def unref(self, block_ids) -> None:
        """Drop a reference; refcount-0 blocks become CACHED (LRU-retained)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            rc = self._refcount[bid]
            if rc <= 0:
                raise ValueError(f"block {bid} not active")
            self._refcount[bid] = rc - 1
            if rc == 1:
                self._cached[bid] = None
                self._cached.move_to_end(bid)

    def touch(self, block_ids) -> None:
        """Refresh LRU position of cached blocks (on prefix hit)."""
        for bid in block_ids:
            if bid in self._cached:
                self._cached.move_to_end(bid)

    def drop(self, block_ids) -> None:
        """Hard-free blocks (invalidated, e.g. schema mismatch)."""
        for bid in block_ids:
            if bid == self.SENTINEL:
                raise ValueError("page 0 is the padding sentinel, never live")
            if bid in self._cached:
                del self._cached[bid]
            self._refcount[bid] = 0
            self._free.append(bid)

    def refcount(self, bid: int) -> int:
        return self._refcount[bid]

    def check_invariants(self) -> None:
        """Property-test hook: every block is in exactly one state."""
        free = set(self._free)
        cached = set(self._cached)
        assert not (free & cached), "block both free and cached"
        assert self.SENTINEL not in free and self.SENTINEL not in cached, \
            "sentinel page 0 entered the pool"
        assert self._refcount[self.SENTINEL] == 0, "sentinel page 0 is live"
        for bid in range(1, self.num_blocks + 1):
            rc = self._refcount[bid]
            if bid in free:
                assert rc == 0, f"free block {bid} has refcount {rc}"
            elif bid in cached:
                assert rc == 0, f"cached block {bid} has refcount {rc}"
            else:
                assert rc > 0, f"active block {bid} has refcount {rc}"
        assert len(free) + len(cached) + sum(
            1 for r in self._refcount if r > 0) == self.num_blocks
