"""PagedKVPool: the physical KV data plane behind the paged control plane.

``BlockPool``/``PrefixIndex``/``CacheManager`` are the *control* plane —
refcounts, LRU, prefix matching over abstract block ids (since the
automatic-prefix-caching PR, ONE engine-global radix tree is the single
source of prefix truth over these pages: a page published by any prefill
worker is matchable by every other). This module gives
those ids physical storage: per-layer K/V page arrays shaped
``(P, page_size, Hkv, head_dim)`` (stacked over the model's scanned layer
groups), so a block id allocated by any prefill worker addresses real tensors
readable by every decode worker. That is the zero-copy handoff invariant of
the shared-prefill design: handing a request to a decode model moves a block
table (a few bytes of page ids), never the KV itself.

Data flow:
  - prefill: ``gather_prefill_cache`` materializes the cached prefix as a
    dense working cache (the compute plane for incremental attention), the
    frozen base model extends it, and ``scatter_from_dense`` writes the fresh
    page-aligned rows back into the pool via the ``paged_write`` Pallas
    kernel (interpret mode off-TPU).
  - decode: ``make_decode_cache`` wires the pool arrays + per-sequence block
    tables into the model cache pytree; ``repro.models.attention`` then runs
    the paged decode-attention step (Pallas kernel on TPU, jnp gather twin
    elsewhere) and appends each generated token's KV to the sequence's
    private tail page; ``absorb_decode_cache`` publishes the updated pages.

Donation-aware decode state: ``decode_state()`` hands the page buffers out as
one pytree to be passed INTO a jitted decode step (donated on TPU, so XLA
updates the touched pages in place instead of functionally copying the pool
per step — mirroring ``copy_page``), and ``absorb_decode_state`` stores the
step's returned buffers back. Off-TPU donation is a no-op and the pair
degrades to the plain functional update.

Physical row 0 is the padding sentinel (``BlockPool.SENTINEL``): it is never
allocated, so ragged block tables zero-padded to a common width can never
alias live KV. The pool therefore carries ``num_pages + 1`` physical rows for
``num_pages`` usable pages (ids 1..num_pages).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.kernels.paged_write import paged_write


def _interp(interpret):
    return (jax.default_backend() != "tpu") if interpret is None else interpret


def _copy_page_impl(state, src, dst):
    # group arrays are (n_full, P, page, Hkv, D): page axis 1; tail arrays
    # are (P, page, Hkv, D): page axis 0.
    def cp(a):
        if a.ndim == 5:
            return a.at[:, dst].set(a[:, src])
        return a.at[dst].set(a[src])
    return jax.tree.map(cp, state)


# One fused, jitted update over the whole pool pytree; donating the pool
# buffers lets XLA update the touched pages in place instead of copying the
# pool per clone (donation is a no-op on backends that ignore it, so only
# request it where it's honoured).
_copy_page_jit = jax.jit(
    _copy_page_impl,
    donate_argnums=(0,) if jax.default_backend() == "tpu" else ())


class PagedKVPool:
    """Per-layer physical K/V page arrays for a pure global-attention stack.

    Layers mirror the model cache structure: full ``layer_pattern`` groups are
    stacked on a leading axis (matching the ``lax.scan`` over groups in
    ``repro.models.model.forward``), remainder tail layers are stored
    individually.
    """

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 dtype=None):
        assert self.supports(cfg), (
            f"paged KV plane requires a pure global-attention decoder "
            f"(got pattern {cfg.layer_pattern}, encdec={cfg.is_encdec})")
        self.cfg = cfg
        self.num_pages = num_pages            # usable pages (ids 1..num_pages)
        self.page_size = page_size
        self.hkv, self.hd = cfg.n_kv_heads, cfg.head_dim
        dt = jnp.dtype(dtype or cfg.dtype)
        pat = cfg.layer_pattern
        self.n_full = cfg.n_layers // len(pat)
        n_tail = cfg.n_layers % len(pat)

        # +1 physical row: row 0 is the never-allocated padding sentinel
        shape = (num_pages + 1, page_size, self.hkv, self.hd)
        self.k_groups = {f"pos{i}": jnp.zeros((self.n_full,) + shape, dt)
                         for i in range(len(pat))} if self.n_full else {}
        self.v_groups = {g: jnp.zeros_like(a) for g, a in self.k_groups.items()}
        self.k_tail = [jnp.zeros(shape, dt) for _ in range(n_tail)]
        self.v_tail = [jnp.zeros(shape, dt) for _ in range(n_tail)]

    @staticmethod
    def supports(cfg: ModelConfig) -> bool:
        """True if every layer's sequence state is global-attention KV."""
        return (all(k == ATTN for k in cfg.layer_kinds())
                and not cfg.is_encdec and cfg.n_heads > 0
                and cfg.input_mode == "tokens")

    @property
    def page_bytes(self) -> int:
        per_layer = 2 * self.page_size * self.hkv * self.hd
        return per_layer * self.cfg.n_layers * jnp.dtype(self.cfg.dtype).itemsize

    # ------------------------------------------------------------------
    # prefill side
    # ------------------------------------------------------------------
    def gather_prefill_cache(self, block_table, n_valid: int):
        """Materialize a dense B=1 working cache whose first ``n_valid`` rows
        come from the pool pages named by ``block_table`` (slots beyond
        ``n_valid`` are masked via kpos=-1)."""
        bt = jnp.asarray(block_table, jnp.int32)
        T = len(block_table) * self.page_size
        f = self.hkv * self.hd
        ar = jnp.arange(T, dtype=jnp.int32)
        kpos = jnp.where(ar < n_valid, ar, -1)[None]          # (1, T)

        groups = {}
        for g, kp in self.k_groups.items():
            k = kp[:, bt].reshape(self.n_full, T, f)[:, None]  # (n_full,1,T,f)
            v = self.v_groups[g][:, bt].reshape(self.n_full, T, f)[:, None]
            groups[g] = {"k": k, "v": v,
                         "kpos": jnp.broadcast_to(kpos, (self.n_full, 1, T))}
        tail = [{"k": kt[bt].reshape(T, f)[None],
                 "v": self.v_tail[i][bt].reshape(T, f)[None],
                 "kpos": kpos}
                for i, kt in enumerate(self.k_tail)]
        return {"groups": groups, "tail": tail}

    def scatter_from_dense(self, cache, block_table, start_page: int,
                           n_new_pages: int, *, interpret=None):
        """Write pages ``[start_page, start_page + n_new_pages)`` of a dense
        B=1 working cache into their physical pool pages (paged_write kernel).

        Rows are taken from the *updated* dense cache, so a page that was
        partially cached before this prefill is rewritten whole — its old
        rows were gathered into the dense cache first, making every write
        page-aligned (the kernel's contract)."""
        if n_new_pages <= 0:
            return
        page = self.page_size
        interp = _interp(interpret)
        bt_tail = jnp.asarray(
            block_table[start_page:start_page + n_new_pages], jnp.int32)[None]
        nvalid = jnp.full((1,), n_new_pages, jnp.int32)
        s0, span = start_page * page, n_new_pages * page

        def rows(leaf_k):                      # (..., 1, cap, f) -> new KV rows
            return leaf_k[..., 0, s0:s0 + span, :].reshape(
                leaf_k.shape[:-3] + (span, self.hkv, self.hd))

        for g in self.k_groups:
            # ONE kernel launch per group: the stacked (n_full, P, page, H, D)
            # layer axis folds into paged_write's batch axis by flattening the
            # pool to (n_full * P, ...) and offsetting each layer's block
            # table by its pool stride — no per-layer Python loop, and no
            # per-prefill ``jnp.stack`` rebuild of the group array.
            kc, vc = rows(cache["groups"][g]["k"]), rows(cache["groups"][g]["v"])
            kg, vg = self.k_groups[g], self.v_groups[g]
            P = kg.shape[1]
            off = (jnp.arange(self.n_full, dtype=jnp.int32) * P)[:, None]
            bt_l = bt_tail[0][None] + off                     # (n_full, npages)
            nv_l = jnp.broadcast_to(nvalid, (self.n_full,))
            kp, vp = paged_write(kc, vc,
                                 kg.reshape((self.n_full * P,) + kg.shape[2:]),
                                 vg.reshape((self.n_full * P,) + vg.shape[2:]),
                                 bt_l, nv_l, interpret=interp)
            self.k_groups[g] = kp.reshape(kg.shape)
            self.v_groups[g] = vp.reshape(vg.shape)
        for i in range(len(self.k_tail)):
            kc, vc = rows(cache["tail"][i]["k"]), rows(cache["tail"][i]["v"])
            self.k_tail[i], self.v_tail[i] = paged_write(
                kc[None], vc[None], self.k_tail[i], self.v_tail[i],
                bt_tail, nvalid, interpret=interp)

    # ------------------------------------------------------------------
    # decode side
    # ------------------------------------------------------------------
    def copy_page(self, src: int, dst: int):
        """Copy-on-write: clone one physical page (all layers) in a SINGLE
        jitted, donated update — one dispatch for the whole pool pytree
        instead of an un-jitted ``.at[].set`` per layer array (which cost
        O(pool) traffic per clone). Used when a decode holder must append
        into a partially-filled shared page."""
        new = _copy_page_jit(self.pool_state(), jnp.int32(src), jnp.int32(dst))
        self.set_pool_state(new)

    def pool_state(self):
        """Every page buffer as ONE pytree — the argument for a jitted,
        donated whole-pool update (``copy_page``'s clone, the swap tier's
        scatter-on-resume). Pair with ``set_pool_state`` on the result.

        Containers are fresh (shallow) copies so the handed-out tree can be
        invalidated independently of the pool's own references (the
        sanitized pool poisons stale handles in place)."""
        return {"kg": dict(self.k_groups), "vg": dict(self.v_groups),
                "kt": list(self.k_tail), "vt": list(self.v_tail)}

    def set_pool_state(self, new) -> None:
        """Store the buffers a jitted whole-pool update returned. After a
        donated TPU update the previous buffers are invalid (the sanitized
        pool poisons them)."""
        self.k_groups, self.v_groups = new["kg"], new["vg"]
        self.k_tail, self.v_tail = list(new["kt"]), list(new["vt"])

    def decode_state(self):
        """The pool's page buffers as ONE pytree, to be passed INTO a jitted
        decode step as an argument (donate it on TPU: pages then update in
        place instead of the per-step functional pool copy). Pair with
        ``absorb_decode_state`` on the step's return value."""
        return {"groups": {g: {"k": self.k_groups[g], "v": self.v_groups[g]}
                           for g in self.k_groups},
                "tail": [{"k": k, "v": v}
                         for k, v in zip(self.k_tail, self.v_tail)]}

    def absorb_decode_state(self, state) -> None:
        """Store the page buffers a jitted decode step returned. After a
        donated TPU step the previous buffers are invalid; off-TPU donation
        is a no-op and this is a plain functional publish."""
        for g in self.k_groups:
            self.k_groups[g] = state["groups"][g]["k"]
            self.v_groups[g] = state["groups"][g]["v"]
        for i in range(len(self.k_tail)):
            self.k_tail[i] = state["tail"][i]["k"]
            self.v_tail[i] = state["tail"][i]["v"]

    @staticmethod
    def wire_decode_cache(state, block_tables, n_full: int):
        """Wire a ``decode_state`` pytree + per-sequence block tables into a
        model cache pytree (traceable: usable inside a jitted/vmapped step)."""
        bt = jnp.asarray(block_tables, jnp.int32)
        groups = {g: {"k_pages": st["k"], "v_pages": st["v"],
                      "block_tables": jnp.broadcast_to(
                          bt, (n_full,) + bt.shape)}
                  for g, st in state["groups"].items()}
        tail = [{"k_pages": st["k"], "v_pages": st["v"], "block_tables": bt}
                for st in state["tail"]]
        return {"groups": groups, "tail": tail}

    def make_decode_cache(self, block_tables, state=None):
        """Wire the pool + per-sequence block tables into a model cache
        pytree for a batched decode step (see attention.attn_apply)."""
        return self.wire_decode_cache(
            self.decode_state() if state is None else state,
            block_tables, self.n_full)

    def absorb_decode_cache(self, new_cache):
        """Publish the page arrays a decode step returned (functional update:
        the step appended one KV row per sequence to its tail page)."""
        for g in self.k_groups:
            self.k_groups[g] = new_cache["groups"][g]["k_pages"]
            self.v_groups[g] = new_cache["groups"][g]["v_pages"]
        for i in range(len(self.k_tail)):
            self.k_tail[i] = new_cache["tail"][i]["k_pages"]
            self.v_tail[i] = new_cache["tail"][i]["v_pages"]
