"""Host-memory KV swap tier: the data plane of preemption.

Oversubscription (serving/preempt.py) parks a low-priority decode sequence
by moving its PRIVATE pages' KV off the device: a single jitted gather per
swap-out pulls every selected page across all layer groups in one launch,
``jax.device_get`` lands the rows in host memory, and the pool rows become
SWAPPED (reclaimable — ``BlockPool.alloc`` may hand them to new owners).
On resume, pages whose device rows were never revoked reattach with zero
data movement; revoked ones are scattered back into freshly allocated rows
with a single jitted, donated whole-pool update (the ``copy_page`` idiom:
donate on TPU so XLA writes the pages in place).

This module is the ONE sanctioned host-materialization point for pool page
buffers: analysis rule RPR007 flags ``np.asarray``/``jax.device_get`` on
``PagedKVPool`` arrays anywhere else.

Page-count shapes are bucketed to the next power of two before entering the
jitted gather/scatter (RPR004): pad slots index the padding sentinel row 0,
which holds no live KV by construction — padded gather rows are sliced off
after the host copy, and padded scatter slots write zeros to row 0, which
no block table can ever read as live KV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1): the page-count shape bucket."""
    return 1 << max(0, int(n) - 1).bit_length()


def _gather_impl(state, bids):
    # group arrays are (n_full, P+1, page, Hkv, D): page axis 1; tail arrays
    # are (P+1, page, Hkv, D): page axis 0 (same layout as copy_page).
    return jax.tree.map(
        lambda a: a[:, bids] if a.ndim == 5 else a[bids], state)


def _scatter_impl(state, bids, vals):
    def sc(a, v):
        if a.ndim == 5:
            return a.at[:, bids].set(v)
        return a.at[bids].set(v)
    return jax.tree.map(sc, state, vals)


# Gather reads the pool (no donation: the pool stays live); scatter rewrites
# it wholesale, so the pool pytree is donated where donation is honoured —
# exactly the copy_page contract, one launch per swap either way.
_gather_jit = jax.jit(_gather_impl)
_scatter_jit = jax.jit(
    _scatter_impl,
    donate_argnums=(0,) if jax.default_backend() == "tpu" else ())


class HostSwapPool:
    """rid-keyed host-memory store of swapped-out page KV.

    ``put`` copies pages device->host (timed, fed to the bandwidth model);
    ``restore`` scatters a subset of an entry's pages back into fresh device
    rows; ``pop`` discards the host copy (resume complete, or abort while
    swapped). ``observe(nbytes, seconds)`` — when given — receives every
    measured transfer so the preemption cost model prices swap vs recompute
    from measured bandwidth, not constants.
    """

    def __init__(self, observe=None):
        self._entries: dict = {}      # rid -> {"bids": list, "host": pytree}
        self.observe = observe
        self.total_bytes = 0

    def __contains__(self, rid) -> bool:
        return rid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def bids(self, rid):
        return self._entries[rid]["bids"]

    # ------------------------------------------------------------------
    def put(self, kvpool, rid, bids) -> int:
        """Copy pages ``bids`` (all layers) to host memory under ``rid``.
        One jitted gather launch + one host transfer; returns bytes moved."""
        assert rid not in self._entries, f"rid {rid} already swapped"
        n = len(bids)
        width = next_pow2(n)
        idx = jnp.asarray(list(bids) + [0] * (width - n), jnp.int32)
        t0 = time.perf_counter()
        gathered = _gather_jit(kvpool.pool_state(), idx)
        host = jax.device_get(gathered)
        dt = time.perf_counter() - t0
        # drop the pad rows landed by the pow2 bucket
        host = jax.tree.map(
            lambda a: a[:, :n] if a.ndim == 5 else a[:n], host)
        nbytes = n * kvpool.page_bytes
        self._entries[rid] = {"bids": list(bids), "host": host}
        self.total_bytes += nbytes
        if self.observe is not None and n:
            self.observe(nbytes, dt)
        return nbytes

    def restore(self, kvpool, rid, positions, dst_bids) -> int:
        """Scatter the entry's pages at ``positions`` back into device rows
        ``dst_bids`` (one donated whole-pool launch); returns bytes moved.
        Pages NOT in ``positions`` were never revoked and need no transfer."""
        entry = self._entries[rid]
        n = len(positions)
        if n == 0:
            return 0
        assert len(dst_bids) == n
        width = next_pow2(n)
        idx = jnp.asarray(list(dst_bids) + [0] * (width - n), jnp.int32)
        sel = np.asarray(positions, np.intp)

        def pick(a):
            # page axis sized to the pow2 bucket up front; pad slots stay
            # zero and scatter onto sentinel row 0 (never read as live KV)
            axis = 1 if a.ndim == 5 else 0
            shape = (list(a.shape[:axis]) + [next_pow2(n)]
                     + list(a.shape[axis + 1:]))
            out = np.zeros(shape, a.dtype)
            if a.ndim == 5:
                out[:, :n] = a[:, sel]
            else:
                out[:n] = a[sel]
            return out

        vals = jax.tree.map(pick, entry["host"])
        t0 = time.perf_counter()
        new = _scatter_jit(kvpool.pool_state(), idx, vals)
        new = jax.block_until_ready(new)
        kvpool.set_pool_state(new)
        dt = time.perf_counter() - t0
        nbytes = n * kvpool.page_bytes
        if self.observe is not None:
            self.observe(nbytes, dt)
        return nbytes

    def pop(self, rid) -> None:
        """Discard ``rid``'s host copy (resume complete, or abort)."""
        entry = self._entries.pop(rid, None)
        if entry is not None:
            self.total_bytes -= len(entry["bids"]) * _entry_page_bytes(entry)

    def entry_pages(self, rid) -> int:
        return len(self._entries[rid]["bids"])


def _entry_page_bytes(entry) -> int:
    """Bytes per page of a stored entry, from its own leaves (the pool that
    produced it may already be gone at pop time)."""
    total = sum(a.nbytes for a in jax.tree.leaves(entry["host"]))
    n = len(entry["bids"])
    return total // n if n else 0
