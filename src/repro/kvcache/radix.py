"""Block-granular radix/prefix index over token sequences.

SGLang-RadixAttention-style prefix reuse at page granularity: each node owns
one physical block and is keyed by that block's token content, chained from
its parent (equivalent to vLLM's chained block hashing, but kept as an
explicit tree so eviction can walk leaves first and subtree reuse is O(depth)).

Deployment shapes:
  - ENGINE-GLOBAL (the default since the automatic-prefix-caching PR): ONE
    ``PrefixIndex`` instance is shared by every prefill worker's
    ``CacheManager`` over the engine's shared ``BlockPool``, so any prompt —
    no explicit SharedContext needed — starts its prefill at the longest
    prefix ANY worker ever published. The pool's eviction callback removes
    evicted blocks from the tree, so no manager can serve a stale match.
  - per-manager (the simulator's baseline mode, and any manager constructed
    without an explicit ``index=``): prefix locality stays private, which is
    what baseline/PrefillShare comparisons measure.
  - ``NullPrefixIndex``: the ``prefix_cache=False`` A/B escape hatch — every
    lookup misses, nothing is published, outputs are bit-identical (prefix
    reuse only ever skips recomputation of identical KV).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Node:
    key: tuple                    # the block's tokens
    block_id: int
    parent: Optional["Node"]
    children: dict = field(default_factory=dict)
    seq: int = 0                  # LRU clock
    provenance: str = "prefill"   # who wrote the page: prefill | relay

    @property
    def is_leaf(self):
        return not self.children


class PrefixIndex:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = Node(key=(), block_id=-1, parent=None)
        self._by_block: dict[int, Node] = {}
        self._clock = 0

    # ------------------------------------------------------------------
    def match(self, tokens) -> tuple[list[int], int]:
        """Longest cached prefix: returns (block_ids, n_tokens_matched)."""
        bs = self.block_size
        node = self.root
        blocks = []
        self._clock += 1
        for i in range(0, len(tokens) - len(tokens) % bs, bs):
            key = tuple(tokens[i:i + bs])
            child = node.children.get(key)
            if child is None:
                break
            child.seq = self._clock
            blocks.append(child.block_id)
            node = child
        return blocks, len(blocks) * bs

    def match_len(self, tokens) -> int:
        """Length (in tokens) of the longest cached prefix of ``tokens``,
        WITHOUT touching the LRU clock — a pure peek for routing/admission
        pricing (the router consults every candidate worker; only the worker
        that actually serves the request should refresh recency)."""
        bs = self.block_size
        node = self.root
        n = 0
        for i in range(0, len(tokens) - len(tokens) % bs, bs):
            child = node.children.get(tuple(tokens[i:i + bs]))
            if child is None:
                break
            n += bs
            node = child
        return n

    def insert(self, tokens, block_ids) -> int:
        """Register fully-filled blocks for ``tokens``; returns #new nodes.
        ``block_ids[i]`` holds tokens[i*bs:(i+1)*bs]."""
        bs = self.block_size
        node = self.root
        new = 0
        self._clock += 1
        for i, bid in enumerate(block_ids):
            seg = tuple(tokens[i * bs:(i + 1) * bs])
            if len(seg) < bs:
                break                         # partial block: not indexable
            child = node.children.get(seg)
            if child is None:
                child = Node(key=seg, block_id=bid, parent=node)
                node.children[seg] = child
                self._by_block[bid] = child
                new += 1
            child.seq = self._clock
            node = child
        return new

    def insert_pages(self, tokens, block_ids, *,
                     provenance: str = "relay") -> list[int]:
        """Adopt already-written pool pages into the tree (relay publication:
        a finished sequence's decode-provenance KV entering the prefix cache
        keyed by its full token stream). Like ``insert``, but returns the
        block ids actually ADOPTED as new nodes — a page whose token segment
        an existing node already serves is NOT adopted (the incumbent keeps
        serving it; the caller must keep dropping its duplicate copy). New
        nodes carry ``provenance`` so stats and the sanitizer can tell
        relay-published pages from prefill-published ones; pages already in
        the tree keep the provenance of whoever wrote them first."""
        bs = self.block_size
        node = self.root
        adopted: list[int] = []
        self._clock += 1
        for i, bid in enumerate(block_ids):
            seg = tuple(tokens[i * bs:(i + 1) * bs])
            if len(seg) < bs:
                break                     # partial block: not indexable
            child = node.children.get(seg)
            if child is None:
                child = Node(key=seg, block_id=bid, parent=node,
                             provenance=provenance)
                node.children[seg] = child
                self._by_block[bid] = child
                adopted.append(bid)
            child.seq = self._clock
            node = child
        return adopted

    def relay_tokens(self, block_ids) -> int:
        """Tokens among ``block_ids`` served by RELAY-provenance nodes (pages
        the decode plane wrote, published at sequence finish) — the relay
        share of a prefix hit, for ``CacheStats`` accounting."""
        by = self._by_block
        return sum(self.block_size for bid in block_ids
                   if bid in by and by[bid].provenance == "relay")

    @property
    def relay_nodes(self) -> int:
        """Tree nodes whose page holds decode-written (relay-published) KV."""
        return sum(1 for nd in self._by_block.values()
                   if nd.provenance == "relay")

    def remove_block(self, block_id: int) -> None:
        """Pool evicted this block: drop its node (subtree must re-prefill).

        Interior-node eviction orphans descendants; we drop the whole subtree
        (matching vLLM semantics where a chain is broken by a missing link)."""
        node = self._by_block.pop(block_id, None)
        if node is None:
            return
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        # unregister descendants
        stack = list(node.children.values())
        while stack:
            n = stack.pop()
            self._by_block.pop(n.block_id, None)
            stack.extend(n.children.values())
        node.children.clear()

    def lru_leaves(self, n: int) -> list[int]:
        """The n least-recently-used leaf blocks (eviction candidates)."""
        leaves = [nd for nd in self._by_block.values() if nd.is_leaf]
        leaves.sort(key=lambda nd: nd.seq)
        return [nd.block_id for nd in leaves[:n]]

    def __len__(self):
        return len(self._by_block)

    def check_invariants(self):
        for bid, node in self._by_block.items():
            assert node.block_id == bid
            assert node.parent is not None
            assert node.parent.children.get(node.key) is node
            # every ancestor is registered (no orphan chains)
            p = node.parent
            while p is not self.root:
                assert p.block_id in self._by_block
                p = p.parent


class NullPrefixIndex:
    """Prefix caching disabled (``prefix_cache=False``): the same interface,
    but every match misses and nothing is ever published. Requests then
    recompute their full prompt (minus the per-session fast paths), which is
    the A/B baseline automatic prefix caching is measured against."""

    def __init__(self, block_size: int = 0):
        self.block_size = block_size

    def match(self, tokens):
        return [], 0

    def match_len(self, tokens) -> int:
        return 0

    def insert(self, tokens, block_ids) -> int:
        return 0

    def insert_pages(self, tokens, block_ids, *,
                     provenance: str = "relay") -> list:
        return []

    def relay_tokens(self, block_ids) -> int:
        return 0

    @property
    def relay_nodes(self) -> int:
        return 0

    def remove_block(self, block_id: int) -> None:
        pass

    def lru_leaves(self, n: int) -> list:
        return []

    def __len__(self):
        return 0

    def check_invariants(self):
        pass
