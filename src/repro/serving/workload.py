"""Multi-model agent workload generators (paper §4.1 inference setup).

Each session runs a four-agent multi-turn workflow; in every turn all agents
are invoked sequentially over a largely shared prefix. Token-length profiles
follow the ReAct / Reflexion statistics used by the paper (via Kim et al.
2025): fixed per-invocation input/output lengths, immediate next-request on
completion, Poisson session arrivals.

Tokens are deterministic synthetic ids so prefix caching sees real prefix
structure: a session's context is an append-only token list; each invocation
appends its (agent-specific) instruction delta, then the generated tokens are
appended by the engine, exactly matching the paper's prompt-construction rule.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Invocation:
    model_id: int           # which specialized decoder
    delta_tokens: int       # new context tokens appended before this call
    gen_tokens: int         # tokens this call generates


@dataclass
class Session:
    sid: int
    arrival: float
    invocations: list       # [Invocation]
    system_tokens: int

    def fresh_tokens(self, n: int, salt: int) -> list[int]:
        """Deterministic token ids: identical across models/workers so prefix
        caches agree, unique across (session, salt) so sessions don't alias."""
        rng = np.random.default_rng((1234 + self.sid) * 1_000_003 + salt)
        return rng.integers(100, 50_000, size=n).tolist()


# Per-invocation (input-delta, output) token profiles.
PATTERNS = {
    # ReAct: thought/action/observation loops — short deltas, short gens
    "react":     {"system": 512, "delta": 160, "gen": 128, "turns": 3},
    # Reflexion: adds self-reflection text — longer generations
    "reflexion": {"system": 512, "delta": 96,  "gen": 256, "turns": 4},
}


def make_sessions(pattern: str, *, n_sessions: int, arrival_rate: float,
                  n_models: int = 4, seed: int = 0) -> list[Session]:
    prof = PATTERNS[pattern]
    rng = np.random.default_rng(seed)
    # Poisson arrivals
    gaps = rng.exponential(1.0 / arrival_rate, size=n_sessions)
    arrivals = np.cumsum(gaps)
    sessions = []
    for sid in range(n_sessions):
        invs = []
        for _turn in range(prof["turns"]):
            for agent in range(n_models):
                invs.append(Invocation(
                    model_id=agent,
                    delta_tokens=prof["delta"],
                    gen_tokens=prof["gen"]))
        sessions.append(Session(sid=sid, arrival=float(arrivals[sid]),
                                invocations=invs,
                                system_tokens=prof["system"]))
    return sessions


# Diurnal two-phase profiles: the workload MIX flips mid-run, which is what
# makes any static prefill:decode split wrong in one of the phases — the
# autoscaler's test scenario (serving/autoscale.py; benchmarks/
# autoscale_sim.py gates autoscale vs every static split on p95 TTFT).
#
# The two phases stress OPPOSITE resources:
#   - prefill_heavy is a BURST of single-turn long-prompt sessions (4x the
#     base arrival rate): prefill queueing dominates TTFT, while the tiny
#     generations mean KV residency drains immediately — decode never
#     becomes the bottleneck no matter how few decode workers remain.
#   - decode_heavy is slow-arriving long-lived chat: trivial prompt work,
#     but accumulated multi-turn KV saturates decode HBM, so TTFT degrades
#     through deferred handoffs (B.2 backpressure) unless decode holds
#     enough workers.
DIURNAL_PHASES = {
    # "daytime" ingest: burst of long cold prompts, terse answers
    "prefill_heavy": {"system": 2048, "delta": 2048, "gen": 16, "turns": 1,
                      "rate_scale": 8.0},
    # "evening" chat: short deltas, long generations, long-lived KV
    "decode_heavy":  {"system": 256,  "delta": 48,  "gen": 512, "turns": 3,
                      "rate_scale": 0.75},
}


def make_diurnal_sessions(*, n_sessions: int, arrival_rate: float,
                          n_models: int = 4, seed: int = 0,
                          phases=("prefill_heavy", "decode_heavy"),
                          phase_gap_s: float = 0.0) -> list[Session]:
    """Two phases of ``n_sessions // 2`` Poisson arrivals each, the second
    starting ``phase_gap_s`` after the first's arrivals end. Sessions run
    much longer than their arrival (multi-turn), so a gap of roughly the
    first phase's drain time is what makes the phases distinct REGIMES
    rather than a blended mix — without it phase-A sessions keep issuing
    prefill-heavy turns all through phase B. Every session keeps the
    paper's all-agents-per-turn structure; only the token mix flips."""
    rng = np.random.default_rng(seed)
    half = n_sessions // 2
    scales = [DIURNAL_PHASES[phases[0 if sid < half else 1]]
              .get("rate_scale", 1.0) for sid in range(n_sessions)]
    gaps = rng.exponential(1.0 / arrival_rate, size=n_sessions) / scales
    arrivals = np.cumsum(gaps)
    arrivals[half:] += phase_gap_s
    sessions = []
    for sid in range(n_sessions):
        prof = DIURNAL_PHASES[phases[0] if sid < half else phases[1]]
        invs = []
        for _turn in range(prof["turns"]):
            for agent in range(n_models):
                invs.append(Invocation(
                    model_id=agent,
                    delta_tokens=prof["delta"],
                    gen_tokens=prof["gen"]))
        sessions.append(Session(sid=sid, arrival=float(arrivals[sid]),
                                invocations=invs,
                                system_tokens=prof["system"]))
    return sessions
