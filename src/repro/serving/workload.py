"""Multi-model agent workload generators (paper §4.1 inference setup).

Each session runs a four-agent multi-turn workflow; in every turn all agents
are invoked sequentially over a largely shared prefix. Token-length profiles
follow the ReAct / Reflexion statistics used by the paper (via Kim et al.
2025): fixed per-invocation input/output lengths, immediate next-request on
completion, Poisson session arrivals.

Tokens are deterministic synthetic ids so prefix caching sees real prefix
structure: a session's context is an append-only token list; each invocation
appends its (agent-specific) instruction delta, then the generated tokens are
appended by the engine, exactly matching the paper's prompt-construction rule.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Invocation:
    model_id: int           # which specialized decoder
    delta_tokens: int       # new context tokens appended before this call
    gen_tokens: int         # tokens this call generates


@dataclass
class Session:
    sid: int
    arrival: float
    invocations: list       # [Invocation]
    system_tokens: int

    def fresh_tokens(self, n: int, salt: int) -> list[int]:
        """Deterministic token ids: identical across models/workers so prefix
        caches agree, unique across (session, salt) so sessions don't alias."""
        rng = np.random.default_rng((1234 + self.sid) * 1_000_003 + salt)
        return rng.integers(100, 50_000, size=n).tolist()


# Per-invocation (input-delta, output) token profiles.
PATTERNS = {
    # ReAct: thought/action/observation loops — short deltas, short gens
    "react":     {"system": 512, "delta": 160, "gen": 128, "turns": 3},
    # Reflexion: adds self-reflection text — longer generations
    "reflexion": {"system": 512, "delta": 96,  "gen": 256, "turns": 4},
}


def make_sessions(pattern: str, *, n_sessions: int, arrival_rate: float,
                  n_models: int = 4, seed: int = 0) -> list[Session]:
    prof = PATTERNS[pattern]
    rng = np.random.default_rng(seed)
    # Poisson arrivals
    gaps = rng.exponential(1.0 / arrival_rate, size=n_sessions)
    arrivals = np.cumsum(gaps)
    sessions = []
    for sid in range(n_sessions):
        invs = []
        for _turn in range(prof["turns"]):
            for agent in range(n_models):
                invs.append(Invocation(
                    model_id=agent,
                    delta_tokens=prof["delta"],
                    gen_tokens=prof["gen"]))
        sessions.append(Session(sid=sid, arrival=float(arrivals[sid]),
                                invocations=invs,
                                system_tokens=prof["system"]))
    return sessions
