"""Small-scale REAL-JAX disaggregated engine on a paged KV data plane.

Runs actual models: a pool of prefill workers hosting the frozen base model
(selected per-session by the PrefillRouter), one shared physical
``PagedKVPool`` whose pages back every allocation the per-worker
``CacheManager``s make, and a set of task-specific decode workers that run
CONTINUOUS-BATCH decode over the pool, sampled per-request.

The public surface is the request-centric API (``repro.serving.api``, see
docs/api.md): ``generate(model_id, tokens, SamplingParams(...))`` returns a
streaming ``RequestOutput`` (per-token callbacks/iterator, finish reasons,
TTFT/ITL timestamps), ``shared_context(prefix)`` opens a first-class shared
prefix that many decode models attach to, and ``abort(request)`` cancels at
any lifecycle stage with page refcounts returned to baseline. SamplingParams
execute inside the jitted decode step; temperature=0 (the default) is the
exact historical greedy graph. The legacy ``submit``/``invoke`` surface
survives as a thin DeprecationWarning shim over the same internals.

The decode-model set is a live lifecycle surface (``engine.models``, a
``repro.serving.registry.ModelRegistry``): models hot-(un)register while the
engine serves — new requests validate against the registry immediately
(first-class ``UnknownModelError``), the fused plane relayouts at step
boundaries with live sequences' lanes remapped bit-identically, and
``unregister`` drains or aborts in-flight work per its ``drain`` flag.
LoRA-spec'd models store one base copy + stacked adapter factors, merged
inside the jitted step (serving/decode.py). A construction-time ``decoders``
dict survives as a DeprecationWarning shim that registers each entry.

The run loop is owned by the chunked-prefill scheduler
(``repro.serving.scheduler``): with ``chunked=True`` each step packs one
decode token per active sequence plus as many prefill chunks as fit a
per-step token budget (chunks attend to the cached prefix straight from the
pool pages via ``flash_prefill_paged`` — no dense gather); with the default
eager mode ``submit`` prefills whole prompts synchronously (the historical
behaviour, kept bit-identical) and the scheduler steps decode only.

Automatic prefix caching (default-on): the per-worker radix indexes are ONE
ENGINE-GLOBAL refcounted radix tree over the shared pool's pages, so EVERY
request — no explicit ``SharedContext`` needed — starts its prefill at the
longest prefix ANY worker ever published (system prompts, few-shot headers,
multi-turn history dedup automatically, fleet-wide). The router can price
the expected prefix-hit length alongside backlog (``prefix_aware`` policy),
the chunked scheduler packs cached-history prefills ahead of cold long
prompts (they finish in a chunk or two and reach decode immediately), and
pool evictions notify the tree before a page re-enters the free list, so a
stale prefix is never served. ``prefix_cache=False`` disables all of it for
A/B: outputs are bit-identical either way (reuse only skips recomputation of
identical KV); ``engine.stats()`` rolls the per-worker hit accounting into
one fleet-wide surface.

Data plane (pure global-attention archs, the paper's operating point):
  - prefill: the router picks a worker; its CacheManager matches the longest
    cached prefix (radix, page-granular) and allocates physical pages for the
    tail; ``base_prefill_paged`` gathers the prefix KV out of the pool,
    extends it with the frozen base model, and scatters the fresh rows back
    into the pages via the ``paged_write`` kernel. The allocation is held for
    the whole session (released in ``end_session``), so a live session's
    pages are never evictable.
  - handoff: ZERO-COPY. The decode side receives a block-table reference and
    takes a refcount on every page; a partially-filled tail page is cloned
    first (page-level copy-on-write) so concurrent decoders can append
    privately. ``handoff_bytes`` counts only the block-table metadata.
  - decode: all active sequences (across sessions AND decode models sharing
    this config) advance one token per engine step in ONE fused, jitted,
    vmapped forward over model-stacked decoder params (serving/decode.py;
    ``fused=False`` restores the per-model dispatch loop), using the paged
    decode-attention step (Pallas kernel on TPU, jnp gather twin elsewhere),
    with generated KV appended to freshly allocated private pages. The pool's
    page buffers are donated into the jitted step on TPU so pages update in
    place. Pages are freed only when the last holder (prefill session or
    decode sequence) releases them.

Relay KV (default-on, ``relay=False`` to A/B): when a sequence FINISHES
decoding, its private decode pages are published into the same engine-global
radix tree, keyed by the full token stream (prompt ⧺ generated tokens) — the
handoff machinery run in reverse. A later request from ANY model whose
prompt extends that stream then starts prefill past the finished sequence's
entire output with a zero-copy block-table reference, extending the paper's
fan-out prefill sharing to sequential agent pipelines (model A's answer is
model B's prompt). Publication is gated on KV-compatibility: only decoders
whose KV path is bit-identical to the frozen base (same full weights, or
differing only in post-KV leaves — the unembed head / final norm) may
publish, so a relayed prefix is always bit-identical to cold prefill.
Full pages are adopted directly; the partial tail was already privatized by
the handoff's page-level CoW, and a still-partial tail at finish is dropped
as before. Aborted sequences never publish.

Archs with non-KV sequence state (SSM/recurrent/hybrid/enc-dec) fall back to
the dense per-session path (``paged=False``), preserving the state-handoff
semantics validated in tests/test_engine_ssm.py.

Prefix-hit accounting comes from the SAME CacheManager bookkeeping the
simulator uses (``Allocation.cached_tokens``), so engine and simulator stats
share one accounting path.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefillshare import (base_prefill, base_prefill_paged,
                                     cache_schema)
from repro.kvcache.blocks import BlockPool, PoolExhausted
from repro.kvcache.handoff import HandoffChannel, transfer_cache
from repro.kvcache.manager import CacheManager, CacheStats
from repro.kvcache.paged import PagedKVPool
from repro.kvcache.radix import NullPrefixIndex, PrefixIndex
from repro.kvcache.sanitize import PoolSanitizer, SanitizedKVPool
from repro.models import forward
from repro.serving.api import (FINISH_ABORT, FINISH_LENGTH, RequestOutput,
                               SamplingParams, SharedContext)
from repro.serving.autoscale import Autoscaler
from repro.serving.backpressure import ThroughputEWMA
from repro.serving.decode import (FusedDecodePlane, next_pow2,
                                  sampling_arrays)
from repro.serving.metrics import (SPAN_FIRST_TOKEN, SPAN_HANDOFF,
                                   SPAN_ROUTED, SPAN_TOKEN, MetricsRegistry)
from repro.serving.preempt import PreemptConfig, SwapManager
from repro.serving.registry import ModelRegistry, as_spec
from repro.serving.router import PrefillRouter
from repro.serving.sampling import sample_step
from repro.serving.scheduler import (ChunkedScheduler, Request,
                                     SchedulerConfig)


@dataclass
class SessionCache:
    """Dense-path session state (SSM/hybrid/enc-dec fallback)."""
    cache: object
    n_tokens: int
    capacity: int
    alloc: object = None          # held until end_session (residency == refs)


@dataclass
class PagedSession:
    alloc: object                 # CacheManager Allocation, held for lifetime
    block_table: list             # physical page per logical page
    n_tokens: int
    tokens: list                  # context (for sibling-submit fast path)


@dataclass
class DecodeSeq:
    """One in-flight generation: a block-table reference into the shared
    pool (zero-copy handoff) plus private pages for generated tokens."""
    rid: int
    sid: int
    model_id: str
    block_table: list
    shared_blocks: list           # refcounted prefix pages (unref on finish)
    private_blocks: list          # CoW tail + generated pages (drop on finish)
    pos: int                      # tokens currently in the cache
    next_token: int               # token whose KV the next step writes
    remaining: int
    params: SamplingParams = field(default_factory=SamplingParams)
    finish_reason: str | None = None   # set on eos/stop; None -> length
    out: list = field(default_factory=list)
    tokens: list = field(default_factory=list)  # prompt (relay publication
                                                # keys pages by full stream)
    first0: int = 2               # the handoff's first decode input token
    priority: int = 0             # preemption rank (serving/preempt.py):
                                  # lower priorities are victims first


class _CounterField:
    """EngineStats field descriptor backed by a registry ``Counter``: reads
    return ints (legacy ``stats.handoffs == 3`` comparisons keep holding),
    writes (``+= n``, ``= 0``) go straight to the counter cell — so the SAME
    number the old attribute surface exposes is what ``engine.metrics()``
    snapshots and ``render_prometheus()`` exports, with no double
    bookkeeping."""

    __slots__ = ("prom", "help", "name")

    def __init__(self, prom: str, help: str = ""):
        self.prom = prom
        self.help = help

    def __set_name__(self, owner, name):
        self.name = name

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return int(obj._cells[self.name].value)

    def __set__(self, obj, v):
        obj._cells[self.name].value = float(v)


class EngineStats:
    """Engine counters, re-implemented as a VIEW over the metrics registry
    (serving/metrics.py): each field is a registry counter cell, so the
    legacy attribute surface (``stats.handoffs += 1``, ``stats() -> dict``)
    and the new observability surface (``engine.metrics()``, Prometheus
    exposition) are the same numbers by construction. Counters stay real
    even with metrics disabled — ``stats()`` predates the registry and must
    keep working either way."""

    prefill_tokens_computed = _CounterField(
        "engine_prefill_tokens_computed_total",
        "prompt tokens actually run through the base prefill model")
    prefill_tokens_reused = _CounterField(
        "engine_prefill_tokens_reused_total",
        "prompt tokens served from cached prefix KV (never recomputed)")
    handoffs = _CounterField(
        "engine_handoffs_total", "prefill->decode cache handoffs")
    handoff_bytes = _CounterField(
        "engine_handoff_bytes_total",
        "handoff wire bytes (paged: block-table metadata only)")
    cow_page_copies = _CounterField(
        "engine_cow_page_copies_total",
        "partial tail pages cloned at handoff (page-level copy-on-write)")
    decode_steps = _CounterField(
        "engine_decode_steps_total", "engine decode steps")
    decode_tokens = _CounterField(
        "engine_decode_tokens_total", "tokens generated across all sequences")
    decode_dispatches = _CounterField(
        "engine_decode_dispatches_total", "jitted decode forwards issued")
    model_churn_events = _CounterField(
        "engine_model_churn_events_total",
        "accepted register/unregister mutations")
    plane_rebuilds = _CounterField(
        "engine_plane_rebuilds_total",
        "fused-plane relayouts applied at step boundaries")
    relay_publishes = _CounterField(
        "engine_relay_publishes_total",
        "finished sequences whose decode KV entered the prefix tree")
    relay_pages_published = _CounterField(
        "engine_relay_pages_published_total",
        "decode-written pages adopted into the radix tree at finish")
    relay_skipped = _CounterField(
        "engine_relay_skipped_total",
        "finished sequences not published (relay-incompatible decoder)")
    preemptions = _CounterField(
        "engine_preemptions_total",
        "decode sequences preempted under pool pressure")
    swap_out_pages = _CounterField(
        "engine_swap_out_pages_total",
        "pages gathered out to the host swap tier")
    swap_in_pages = _CounterField(
        "engine_swap_in_pages_total",
        "pages scattered back from the host swap tier")
    recompute_tokens = _CounterField(
        "engine_recompute_tokens_total",
        "cache-cold tokens re-prefilled to restore dropped victims")
    swap_bytes = _CounterField(
        "engine_swap_bytes_total",
        "KV bytes moved device<->host by the swap tier")

    FIELDS = ("prefill_tokens_computed", "prefill_tokens_reused", "handoffs",
              "handoff_bytes", "cow_page_copies", "decode_steps",
              "decode_tokens", "decode_dispatches", "model_churn_events",
              "plane_rebuilds", "relay_publishes", "relay_pages_published",
              "relay_skipped", "preemptions", "swap_out_pages",
              "swap_in_pages", "recompute_tokens", "swap_bytes")

    def __init__(self, _engine: object = None,
                 registry: MetricsRegistry | None = None):
        self._engine = _engine
        # standalone EngineStats() (DensePrefillWorker default) gets a
        # private registry; the engine passes its own so all surfaces share
        # one set of cells
        self.registry = MetricsRegistry() if registry is None else registry
        cls = type(self)
        self._cells = {
            name: self.registry.counter(cls.__dict__[name].prom,
                                        cls.__dict__[name].help)
            for name in self.FIELDS}

    @property
    def hit_ratio(self):
        tot = self.prefill_tokens_computed + self.prefill_tokens_reused
        return self.prefill_tokens_reused / tot if tot else 0.0

    @property
    def decode_batch_mean(self):
        return self.decode_tokens / self.decode_steps if self.decode_steps else 0.0

    def __call__(self) -> dict:
        """ONE engine-wide stats surface (``engine.stats()``): the counter
        fields above plus the per-worker ``CacheStats`` rolled up fleet-wide
        (prefix-hit tokens / lookups / hit ratio via ``CacheStats.merge`` —
        the same accounting path the simulator reports) and the pool's
        eviction/occupancy counters. Benches and the simulator read this one
        number instead of stitching per-manager fragments."""
        d = {name: getattr(self, name) for name in self.FIELDS}
        d["hit_ratio"] = self.hit_ratio
        d["decode_batch_mean"] = self.decode_batch_mean
        eng = self._engine
        if eng is None:
            return d
        agg = CacheStats.merge(w.mgr.stats for w in eng.prefill_workers)
        pools = ([eng.block_pool] if eng.block_pool is not None
                 else [w.mgr.pool for w in eng.prefill_workers])
        # pages_cached counts EVERY radix-resident evictable page regardless
        # of provenance — prefill-published and decode-(relay-)published pages
        # live in the same pool population; the relay share is split out so
        # dashboards can see how much cache occupancy decode contributed
        idx = eng.prefix_index
        relay_nodes = getattr(idx, "relay_nodes", 0) if idx is not None else 0
        cached_relay = 0
        if (eng.block_pool is not None and idx is not None
                and hasattr(idx, "_by_block")):
            cached = eng.block_pool._cached
            cached_relay = sum(1 for bid, nd in idx._by_block.items()
                               if nd.provenance == "relay" and bid in cached)
        d.update(
            prefix_hit_tokens=agg.hit_tokens,
            prefix_total_tokens=agg.total_tokens,
            prefix_lookups=agg.lookups,
            prefix_hit_ratio=agg.hit_ratio,
            relay_hit_tokens=agg.relay_hit_tokens,
            relay_hit_ratio=(agg.relay_hit_tokens / agg.total_tokens
                             if agg.total_tokens else 0.0),
            evictions=sum(p.stats.evictions for p in pools),
            pages_active=sum(p.active_count for p in pools),
            pages_cached=sum(p.cached_count for p in pools),
            pages_cached_relay=cached_relay,
            relay_nodes=relay_nodes,
            prefix_nodes=(len(eng.prefix_index)
                          if eng.prefix_index is not None
                          else sum(len(w.mgr.index)
                                   for w in eng.prefill_workers)),
        )
        swap = getattr(eng, "swap", None)
        d.update(
            pages_swapped=sum(getattr(p, "swapped_count", 0) for p in pools),
            swapped_seqs=len(swap.records) if swap is not None else 0,
        )
        return d


# ======================================================================
# Prefill workers


class PrefillWorker:
    """Paged prefill worker: frozen base model + CacheManager over the
    engine's SHARED physical page pool and (by default) the engine's
    SHARED GLOBAL radix tree, so a prefix published by any worker is a hit
    on every worker."""

    def __init__(self, wid: int, cfg: ModelConfig, base_params,
                 kvpool: PagedKVPool, block_pool: BlockPool,
                 stats: EngineStats, index=None):
        self.wid = wid
        self.cfg = cfg
        self.base_params = base_params
        self.kvpool = kvpool
        self.mgr = CacheManager(cfg, block_pool.num_blocks,
                                block_pool.block_size, pool=block_pool,
                                index=index)
        self.sessions: dict[int, PagedSession] = {}
        self.stats = stats
        self.backlog_s = 0.0      # router load signal (estimated work issued)
        self.last_decay_t = time.monotonic()   # backlog decay clock
        self.ewma = ThroughputEWMA()       # measured prefill s/token
        self.pending_chunk_tokens = 0      # admitted-but-uncomputed (chunked)

    def prefill(self, sid: int, tokens) -> tuple[list, int]:
        """Ensure pool pages cover ``tokens``; compute only the uncached
        tail. Returns (block_table, n_tokens)."""
        tokens = [int(t) for t in np.asarray(tokens)]
        n = len(tokens)
        sc = self.sessions.get(sid)
        if sc is not None and sc.tokens == tokens:
            # sibling submit of the identical context (e.g. several decode
            # models fanning out over one turn): the session's pages already
            # hold it — no acquire, no recompute, no fresh partial page.
            self.mgr.record_hit(n)             # same accounting path
            self.stats.prefill_tokens_reused += n
            return list(sc.block_table), n
        alloc = self.mgr.acquire(tokens)
        n_cached = alloc.cached_tokens
        bt = list(alloc.blocks)
        try:
            if n_cached < n:
                new = jnp.asarray(tokens[n_cached:], jnp.int32)[None]
                t0 = time.perf_counter()
                out = base_prefill_paged(self.cfg, self.base_params, new,
                                         pool=self.kvpool, block_table=bt,
                                         n_cached=n_cached)
                jax.block_until_ready(out)
                self.ewma.observe(n - n_cached, time.perf_counter() - t0)
        except BaseException:
            # nothing was committed: tail pages hold partial KV and must be
            # hard-freed, cached prefix refs go back (RPR002 discipline)
            self.mgr.abandon(alloc)
            raise
        self.mgr.commit(tokens, alloc)
        if sc is not None:
            self.mgr.release(sc.alloc)     # swap, don't drop: new alloc holds
        self.sessions[sid] = PagedSession(alloc, bt, n, tokens)
        self.stats.prefill_tokens_computed += n - n_cached
        self.stats.prefill_tokens_reused += n_cached
        self.backlog_s += (n - n_cached) * self.ewma.s_per_token
        return bt, n

    def end_session(self, sid: int):
        sc = self.sessions.pop(sid, None)
        if sc is not None:
            self.mgr.release(sc.alloc)     # pages -> CACHED (LRU, reusable)


class DensePrefillWorker:
    """Dense fallback: one incrementally-extended cache per session (archs
    whose sequence state is not paged KV). The page-level CacheManager still
    runs for accounting, and — unlike the seed — the allocation is HELD for
    the session lifetime so residency matches the refcounts."""

    def __init__(self, cfg: ModelConfig, base_params, *, capacity: int = 512,
                 mgr_blocks: int = 4096, block_size: int = 16,
                 stats: EngineStats | None = None, index=None):
        self.cfg = cfg
        self.base_params = base_params
        self.schema = cache_schema(cfg, base_params, capacity)
        self.capacity = capacity
        self.sessions: dict[int, SessionCache] = {}
        self.mgr = CacheManager(cfg, mgr_blocks, block_size, index=index)
        self.stats = stats if stats is not None else EngineStats()
        self.backlog_s = 0.0
        self.last_decay_t = time.monotonic()
        self.ewma = ThroughputEWMA()
        self.pending_chunk_tokens = 0

    def prefill(self, sid: int, tokens) -> SessionCache:
        tokens = np.asarray(tokens)
        n = len(tokens)
        sc = self.sessions.get(sid)
        alloc = self.mgr.acquire(tokens.tolist())      # block-level metrics
        self.mgr.commit(tokens.tolist(), alloc)
        t0 = time.perf_counter()
        try:
            if sc is None:
                _, cache = base_prefill(
                    self.cfg, self.base_params, jnp.asarray(tokens)[None],
                    cache_len=max(self.capacity, n))
                jax.block_until_ready(cache)
                self.ewma.observe(n, time.perf_counter() - t0)
                new = SessionCache(cache, n, max(self.capacity, n), alloc)
                self.stats.prefill_tokens_computed += n
            else:
                assert n > sc.n_tokens, "context is append-only"
                fresh = tokens[sc.n_tokens:]
                _, cache = base_prefill(
                    self.cfg, self.base_params, jnp.asarray(fresh)[None],
                    cache_len=sc.capacity, cache=sc.cache,
                    pos=jnp.array([sc.n_tokens], jnp.int32))
                jax.block_until_ready(cache)
                self.ewma.observe(len(fresh), time.perf_counter() - t0)
                self.stats.prefill_tokens_computed += len(fresh)
                self.stats.prefill_tokens_reused += sc.n_tokens
                self.mgr.release(sc.alloc)
                new = SessionCache(cache, n, sc.capacity, alloc)
        except BaseException:
            # already committed above, so the pages are published: release
            # (-> CACHED) rather than abandon, mirroring end_session
            self.mgr.release(alloc)
            raise
        self.sessions[sid] = new
        self.backlog_s += n * self.ewma.s_per_token
        return new

    def end_session(self, sid: int):
        sc = self.sessions.pop(sid, None)
        if sc is not None and sc.alloc is not None:
            self.mgr.release(sc.alloc)


# ======================================================================
# Decode


class DecodeWorker:
    """Hosts ONE task-specific decode module (cache-conditioned).

    Paged mode: ``step`` advances every assigned sequence by one token in a
    single batched forward (continuous batching over the shared pool).
    Dense mode: ``generate`` is the legacy B=1 loop over a private cache.

    Weights come from a ``DecodeModelSpec`` and materialize LAZILY: a
    LoRA-spec'd model only pays for full ``lora_apply`` params if one of the
    per-model paths (``fused=False`` loop, dense fallback) actually runs it —
    the fused plane reads the adapter factors straight from the registry and
    never touches this copy."""

    #: may this model's decode-written KV be relay-published as shared
    #: prefix? Set by the engine at attach time (KV path bit-identical to
    #: the frozen base); False for directly-constructed workers.
    relay_compatible = False

    def __init__(self, cfg: ModelConfig, model_id: str, spec,
                 expected_schema, base_params=None):
        self.cfg = cfg
        self.model_id = model_id
        self.spec = as_spec(spec)
        self.base_params = base_params
        self.expected_schema = expected_schema
        self._dec_params = None
        self._step = None

    @property
    def dec_params(self):
        if self._dec_params is None:
            self._dec_params = self.spec.materialize(self.base_params)
        return self._dec_params

    # ---- paged continuous batching ----
    def step(self, tokens, pos, cache, temps, top_ks, top_ps, seeds,
             greedy_only):
        """One batched decode step: feed ``tokens`` (B,) at positions ``pos``
        (B,), paged cache attached, per-sequence sampling controls (B,)-
        aligned; returns (next_tokens (B,), new_cache). Sampling runs inside
        the jitted step; temperature=0 rows are exact argmax (the historical
        greedy path, bit-identical), and an all-greedy batch (``greedy_only``
        static flag) traces an argmax-only step with no sampling graph."""
        if self._step is None:
            cfg = self.cfg

            def _step(params, toks, pos, cache, temps, top_ks, top_ps,
                      seeds, greedy_only):
                logits, new_cache, _ = forward(cfg, params, toks[:, None],
                                               cache=cache, pos=pos)
                if greedy_only:
                    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                else:
                    nxt = sample_step(logits, pos, temps, top_ks, top_ps,
                                      seeds)
                return nxt, new_cache

            # jit keyed on (B, npages) shapes + the binary greedy_only flag;
            # retraces only when the batch composition or table width
            # changes (sampling controls are VALUES, never trace keys). The
            # cache (pool pages + block tables) is donated where donation is
            # honoured, so the step appends KV in place;
            # make_decode_cache/absorb_decode_cache are the donation-aware
            # pair around this call.
            donate = (3,) if jax.default_backend() == "tpu" else ()
            self._step = jax.jit(_step, donate_argnums=donate,
                                 static_argnums=(8,))
        return self._step(self.dec_params, tokens, pos, cache,
                          temps, top_ks, top_ps, seeds, greedy_only)

    # ---- dense fallback ----
    def generate(self, cache, start_pos: int, first_token: int,
                 params: SamplingParams) -> tuple[np.ndarray, str]:
        """Legacy B=1 dense loop, now under the same SamplingParams contract
        as the paged planes. Returns (tokens, finish_reason)."""
        cfg = self.cfg
        pos = jnp.array([start_pos], jnp.int32)
        tok = jnp.array([first_token], jnp.int32)
        samp = jnp.asarray([params.temperature], jnp.float32), \
            jnp.asarray([params.top_k], jnp.int32), \
            jnp.asarray([params.top_p], jnp.float32), \
            jnp.asarray([params.seed or 0], jnp.int32)
        greedy_only = params.temperature <= 0
        out, reason = [], FINISH_LENGTH
        for _ in range(params.max_tokens):
            logits, cache, _ = forward(cfg, self.dec_params, tok[:, None],
                                       cache=cache, pos=pos)
            if greedy_only:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                tok = sample_step(logits, pos, *samp)
            t = int(tok[0])
            out.append(t)
            pos = pos + 1
            stop = params.is_stop(t)
            if stop is not None:
                reason = stop
                break
        return np.asarray(out, np.int32), reason


# ======================================================================
# Engine


class LocalDisaggEngine:
    """Proxy + prefill worker pool + heterogeneous decode pool over one
    shared paged KV plane (Appendix B.1, upgraded to the §3.3 pipeline)."""

    def __init__(self, cfg: ModelConfig, base_params, decoders: dict | None = None, *,
                 capacity: int = 512, paged: bool | None = None,
                 num_pages: int = 1024, page_size: int = 16,
                 n_prefill_workers: int = 1, router_policy: str = "pinned",
                 chunked: bool = False, token_budget: int = 256,
                 chunk_size: int = 64, sched_policy: str = "fcfs",
                 fused: bool | None = None, prefix_cache: bool = True,
                 relay: bool = True, metrics: bool = True, autoscale=None,
                 sanitize: bool = False, preempt: bool = False,
                 overcommit: float = 1.0):
        self.cfg = cfg
        self.base_params = base_params
        self.page_size = page_size
        # observability control plane (serving/metrics.py): ONE registry the
        # engine, router, scheduler, pool, and prefix index publish into —
        # engine.metrics() / engine.render_prometheus() export it.
        # metrics=False degrades histograms/gauges/traces to shared no-op
        # singletons (the decode hot loop skips observation entirely via
        # _metrics_on); counters stay real because stats() runs on them.
        self._metrics_on = metrics
        self.metrics_registry = MetricsRegistry(enabled=metrics)
        self.stats = EngineStats(_engine=self,
                                 registry=self.metrics_registry)
        self.chunked = chunked
        self.paged = PagedKVPool.supports(cfg) if paged is None else paged
        if self.paged and not PagedKVPool.supports(cfg):
            raise ValueError(f"{cfg.name}: arch not eligible for paged plane")
        self.schema = cache_schema(cfg, base_params, capacity)
        self.handoff = HandoffChannel(cfg)
        self.router = PrefillRouter(n_prefill_workers, router_policy)
        self.prefix_cache = prefix_cache
        # relay KV: publish finished sequences' decode pages into the radix
        # tree (zero-copy pipeline reuse; module docstring). Requires the
        # paged plane and rides on the prefix tree — with prefix_cache=False
        # the Null index adopts nothing, so relay degrades to off by
        # construction. relay=False is the A/B escape hatch (bit-identical).
        self.relay = relay and self.paged and prefix_cache
        if sanitize and not self.paged:
            raise ValueError("sanitize=True requires the paged KV plane "
                             "(the sanitizer checks page refcounts)")
        if preempt and not self.paged:
            raise ValueError("preempt=True requires the paged KV plane "
                             "(the swap tier moves pool pages)")
        if overcommit != 1.0 and not preempt:
            raise ValueError(
                "overcommit > 1 oversubscribes the decode admission reserve "
                "and is only safe with preemption armed; pass preempt=True")
        if self.paged:
            self.block_pool = BlockPool(num_pages, page_size)
            # sanitize=True swaps in the poisoning pool subclass and a
            # step-boundary invariant checker (repro.kvcache.sanitize);
            # token streams stay bit-identical — checks never mutate state
            self.kvpool = (SanitizedKVPool(cfg, num_pages, page_size)
                           if sanitize
                           else PagedKVPool(cfg, num_pages, page_size))
            # automatic prefix caching: ONE engine-global radix tree over the
            # shared pool, shared by every worker's CacheManager — its
            # eviction callback is registered exactly once, here, and fans
            # out to every manager by construction (they all serve matches
            # from this same tree). prefix_cache=False keeps the A/B escape
            # hatch: no cross-request reuse, bit-identical outputs.
            if prefix_cache:
                self.prefix_index = PrefixIndex(page_size)
                self.block_pool.add_evict_callback(
                    self.prefix_index.remove_block)
            else:
                self.prefix_index = NullPrefixIndex(page_size)
            self.prefill_workers = [
                PrefillWorker(i, cfg, base_params, self.kvpool,
                              self.block_pool, self.stats,
                              index=self.prefix_index)
                for i in range(n_prefill_workers)]
        else:
            # dense fallback: per-worker private pools, so block ids are not
            # comparable across workers — the radix tree stays per-manager
            # (prefix_cache=False still disables it for A/B)
            self.block_pool = None
            self.kvpool = None
            self.prefix_index = None
            self.prefill_workers = [
                DensePrefillWorker(cfg, base_params, capacity=capacity,
                                   block_size=page_size, stats=self.stats,
                                   index=None if prefix_cache
                                   else NullPrefixIndex(page_size))
                for _ in range(n_prefill_workers)]
        self.prefill = self.prefill_workers[0]        # 1-worker convenience
        # fused cross-model decode (serving.decode): stack the decoder param
        # pytrees and advance every sequence of every model in ONE vmapped,
        # jitted forward per step. Default on the paged plane; fused=False
        # keeps the per-model dispatch loop (comparison/regression path).
        self.fused = self.paged if fused is None else fused
        assert not (self.fused and not self.paged), \
            "fused decode requires the paged data plane"
        self.scheduler = ChunkedScheduler(
            self, SchedulerConfig(token_budget=token_budget,
                                  chunk_size=chunk_size,
                                  policy=sched_policy))
        #: step-boundary invariant checker (None unless sanitize=True);
        #: the scheduler calls sanitizer.check_step() after every step
        self.sanitizer = PoolSanitizer(self) if sanitize else None
        #: oversubscription subsystem (serving/preempt.py): None unless
        #: preempt=True; the scheduler drives resume/preempt/grow phases and
        #: scales the admission reserve by cfg.overcommit when it is armed
        self.swap = (SwapManager(self, PreemptConfig(overcommit=overcommit))
                     if preempt else None)
        # model lifecycle: the decode-model set lives in the registry
        # (engine.models) and is mutable while serving — register/unregister
        # take effect for new requests immediately and relayout the fused
        # plane at the next step boundary. ``decoders`` at construction is a
        # deprecation shim that registers each entry as a full-weight spec.
        self.decoders: dict[str, DecodeWorker] = {}
        self.models = ModelRegistry(self)
        self.decode_plane = None
        if decoders:
            warnings.warn(
                "LocalDisaggEngine(..., decoders={...}) at construction is "
                "deprecated; use engine.models.register(model_id, "
                "DecodeModelSpec(full=...|lora=...)) — the model set is a "
                "live lifecycle surface now", DeprecationWarning, stacklevel=2)
            for mid, params in decoders.items():
                self.models.register(mid, params)
        if self.fused:
            self._rebuild_decode_plane()
        self.models._dirty = False
        self.stats.model_churn_events = 0     # construction is not churn
        self._results: dict[int, np.ndarray] = {}
        self._fetched: set[int] = set()
        self._aborted: set[int] = set()
        self._next_rid = 0
        self._next_seq = 0
        # request-centric API state: live streaming handles, sessions owned
        # by the engine (SharedContext / one-shot generate) rather than the
        # caller. Context sids live in a high namespace so they can never
        # collide with caller-chosen ints on the legacy surface.
        self._requests: dict[int, RequestOutput] = {}
        self._ephemeral_sids: dict[int, int] = {}      # rid -> auto session
        self._next_ctx_sid = 1 << 40
        self._init_metrics()
        # metrics-driven elastic prefill:decode scaling: an Autoscaler
        # (serving/autoscale.py AutoscaleConfig) consumes the registry's
        # backlog/occupancy/latency signals at STEP BOUNDARIES (the same
        # place model churn applies — scheduler.step after models.sync) and
        # resizes the prefill worker pool / decode admission reserve.
        self._autoscaler = (None if autoscale is None
                            else Autoscaler(autoscale))
        #: extra pool pages held back from prefill chunking and decode
        #: admission on top of the worst-case tail-growth reserve — the
        #: autoscaler's decode-side protection knob (scheduler reads it)
        self.sched_reserve_extra = 0
        #: pages one reserve_delta step moves (quantized so a single
        #: autoscale tick shifts meaningful headroom, not one page)
        self._reserve_quantum = max(1, num_pages // 32)

    #: half-life of the issued-work router signal, in seconds of WALL TIME.
    #: Decay must be a function of elapsed time, not of pick count — a
    #: per-pick multiplicative decay makes the load signal depend on arrival
    #: rate (two bursts a second apart would see completely different
    #: backlogs), which tests/test_router.py pins as a regression.
    BACKLOG_HALFLIFE_S = 0.25

    # ------------------------------------------------------------------
    def _pick_worker(self, sid: int, tokens=None, now: float | None = None):
        # Prefill here is synchronous, so there is no literal queue; the
        # routing signal is recency-weighted issued work plus (in chunked
        # mode) the admitted-but-uncomputed chunk backlog, both priced at
        # the worker's MEASURED s/token EWMA. The issued-work term decays
        # exponentially in ELAPSED TIME (half-life above), which keeps
        # least_loaded balancing while preventing spillover from permanently
        # migrating pinned sessions off an idle worker just because its
        # lifetime total is ahead — and, unlike the old per-pick halving,
        # makes the signal invariant to how often the router is consulted.
        now = time.monotonic() if now is None else now
        for w in self.prefill_workers:
            dt = now - w.last_decay_t
            if dt > 0:
                w.backlog_s *= 0.5 ** (dt / self.BACKLOG_HALFLIFE_S)
                w.last_decay_t = now
        backlogs = [w.backlog_s + w.ewma.backlog_seconds(w.pending_chunk_tokens)
                    for w in self.prefill_workers]
        cold_s = None
        if tokens is not None:
            # expected prefix-hit pricing: the request's cost at a worker is
            # only its COLD tokens — zero on a worker whose session already
            # holds the exact context (fast path), prompt minus the longest
            # radix match otherwise (under the engine-global tree the match
            # is worker-independent; dense fallback keeps per-worker trees,
            # where this term IS the locality signal). match_len is a pure
            # peek: consulting candidates must not refresh LRU recency.
            n = len(tokens)
            cold_s = []
            for w in self.prefill_workers:
                sc = w.sessions.get(sid)
                if sc is not None and getattr(sc, "tokens", None) == tokens:
                    cold = 0
                else:
                    cold = n - w.mgr.index.match_len(tokens)
                cold_s.append(w.ewma.backlog_seconds(cold))
        # the router prices expected completion time in MEASURED seconds:
        # backlog + cold prefill + the measured handoff estimate (EWMA of
        # real zero-copy handoffs — kvcache/handoff.py observe_paged), not
        # the old decorative bandwidth constant
        picked = self.router.pick(sid, now, backlogs, cold_s,
                                  handoff_s=self.handoff.estimate_paged_s())
        if self._metrics_on:
            self._c_router_picks.inc()
            if picked != sid % len(self.prefill_workers):
                self._c_router_nonhome.inc()
        return self.prefill_workers[picked]

    # ------------------------------------------------------------------
    # observability (serving/metrics.py; docs/api.md "Observability")
    # ------------------------------------------------------------------
    def _init_metrics(self) -> None:
        """Bind the engine's instruments. Histograms are created up front so
        hot paths hold direct references (no registry lookups per sample);
        gauges are fn-backed collectors sampled only at export time, so pool
        occupancy / queue depths / radix size cost nothing per step."""
        reg = self.metrics_registry
        self._h_ttft = reg.histogram(
            "engine_ttft_seconds", "submit -> first streamed token",
            lo=1e-5, hi=600.0)
        self._h_itl = reg.histogram(
            "engine_itl_seconds", "gap between consecutive streamed tokens",
            lo=1e-6, hi=60.0)
        self._h_queue = reg.histogram(
            "engine_queue_depth", "waiting+prefilling requests, per step",
            lo=1.0, hi=4096.0, growth=1.5)
        self._h_occ = reg.histogram(
            "engine_page_occupancy",
            "non-free pool page fraction, per step", lo=1e-3, hi=1.0)
        self._h_batch = reg.histogram(
            "engine_decode_batch", "sequences per decode step",
            lo=1.0, hi=4096.0, growth=1.5)
        self._h_handoff_s = reg.histogram(
            "engine_handoff_seconds",
            "measured prefill->decode handoff wall time", lo=1e-7, hi=10.0)
        self._h_handoff_b = reg.histogram(
            "engine_handoff_plan_bytes", "handoff metadata bytes",
            lo=1.0, hi=1e9, growth=2.0)
        self._c_router_picks = reg.counter(
            "engine_router_picks_total", "prefill routing decisions")
        self._c_router_nonhome = reg.counter(
            "engine_router_nonhome_picks_total",
            "routing decisions away from the session's home worker")
        self._c_autoscale = reg.counter(
            "engine_autoscale_decisions_total",
            "autoscaler resize decisions applied")
        reg.gauge("engine_prefill_workers", "live prefill workers",
                  fn=lambda: len(self.prefill_workers))
        reg.gauge("engine_waiting_requests", "requests awaiting admission",
                  fn=lambda: len(self.scheduler.waiting))
        reg.gauge("engine_prefilling_requests", "requests mid-prefill",
                  fn=lambda: len(self.scheduler.prefilling))
        reg.gauge("engine_active_sequences", "sequences decoding",
                  fn=lambda: len(self.scheduler.active))
        reg.gauge("engine_sched_reserve_extra_pages",
                  "autoscaler decode admission reserve (pages)",
                  fn=lambda: self.sched_reserve_extra)
        if self.block_pool is not None:
            reg.gauge("engine_pool_free_pages", "free pool pages",
                      fn=lambda: self.block_pool.free_count)
            reg.gauge("engine_pool_active_pages", "refcount-held pool pages",
                      fn=lambda: self.block_pool.active_count)
            reg.gauge("engine_pool_cached_pages",
                      "LRU-cached (evictable) pool pages",
                      fn=lambda: self.block_pool.cached_count)
            reg.gauge("engine_pool_swapped_pages",
                      "pages whose KV lives in the host swap tier",
                      fn=lambda: self.block_pool.swapped_count)
            reg.gauge("engine_swapped_sequences",
                      "decode sequences parked in the swap tier",
                      fn=lambda: (len(self.swap.records)
                                  if self.swap is not None else 0))
            reg.gauge("engine_swap_host_bytes",
                      "host memory held by swapped-out KV",
                      fn=lambda: (self.swap.host.total_bytes
                                  if self.swap is not None else 0))
        if self.prefix_index is not None:
            reg.gauge("engine_prefix_nodes", "radix prefix-index nodes",
                      fn=lambda: len(self.prefix_index))
            reg.gauge("engine_relay_nodes",
                      "radix nodes holding decode-written (relay) KV",
                      fn=lambda: getattr(self.prefix_index,
                                         "relay_nodes", 0))

    def metrics(self) -> dict:
        """The full observability surface as structured dicts:
        ``{"counters", "gauges", "histograms"}`` (histograms carry
        count/sum/mean/min/max/p50/p95/p99) plus ``"traces"`` — the retained
        per-request lifecycle traces (span-event dicts; see docs/api.md).
        ``engine.stats()`` remains the legacy counter rollup; this is the
        superset it is implemented on."""
        out = self.metrics_registry.snapshot()
        out["traces"] = [t.as_dict() for t in self.metrics_registry.traces()]
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition of every registered metric (what a
        scrape endpoint would serve; linted in CI via
        ``metrics.lint_prometheus``)."""
        return self.metrics_registry.render_prometheus()

    def _observe_step(self) -> None:
        """Per-step occupancy/queue observations (called from the scheduler
        at every step boundary; one histogram sample each, no allocation
        when metrics are disabled)."""
        if not self._metrics_on:
            return
        sched = self.scheduler
        self._h_queue.observe(len(sched.waiting) + len(sched.prefilling))
        if self.block_pool is not None:
            pool = self.block_pool
            self._h_occ.observe(1.0 - pool.free_count / pool.num_blocks)

    # ------------------------------------------------------------------
    # elastic prefill:decode scaling (serving/autoscale.py)
    # ------------------------------------------------------------------
    def _autoscale_signals(self):
        """Assemble the control-loop inputs from the live engine state +
        metric windows (TTFT/ITL p95 read straight off the histograms)."""
        from repro.serving.autoscale import AutoscaleSignals
        sched = self.scheduler
        backlog_tokens = (sum(r.n - r.done for r in sched.prefilling)
                          + sum(r.n for r in sched.waiting))
        rates = [w.ewma.s_per_token for w in self.prefill_workers]
        spt = sum(rates) / len(rates)
        slots = max(sched.cfg.token_budget, 1)
        pool = self.block_pool
        return AutoscaleSignals(
            prefill_backlog_tokens=backlog_tokens,
            prefill_backlog_s=backlog_tokens * spt,
            decode_occupancy=len(sched.active) / slots,
            free_page_frac=(pool.free_count / pool.num_blocks
                            if pool is not None else 1.0),
            ttft_p95_s=self._h_ttft.percentile(95),
            itl_p95_s=self._h_itl.percentile(95),
            n_prefill=len(self.prefill_workers),
            n_decode=1,
            inflight_decode=len(sched.active))

    def _autoscale_tick(self) -> None:
        """Step-boundary resize hook (scheduler.step, right after model
        churn applies — the one place worker-set mutations are legal).
        prefill_delta resizes the REAL worker pool (PR 5 pattern: new
        workers share the pool, the radix tree, and the stats cells, and
        become routable immediately); decode_delta maps onto the decode
        admission reserve — the engine's decode plane is one fused step, so
        "more decode capacity" means holding back pages from prefill so
        promotions never squeeze running generations."""
        if self._autoscaler is None:
            return
        d = self._autoscaler.tick(self._autoscale_signals(),
                                  time.monotonic())
        if d.prefill_delta > 0:
            self._add_prefill_worker()
            self._c_autoscale.inc()
        elif d.prefill_delta < 0:
            if self._remove_prefill_worker():
                self._c_autoscale.inc()
        if d.decode_delta:
            cap = self.block_pool.num_blocks if self.block_pool else 0
            self.sched_reserve_extra = min(
                max(self.sched_reserve_extra
                    + d.decode_delta * self._reserve_quantum, 0),
                cap // 2)

    def _add_prefill_worker(self) -> None:
        """Grow the prefill pool by one worker sharing the engine's page
        pool, global radix tree, and stats cells; the router sees it for the
        next pick. Paged plane only (the dense fallback has per-worker
        private pools that cannot be hot-joined)."""
        assert self.paged, "elastic prefill pool requires the paged plane"
        w = PrefillWorker(len(self.prefill_workers), self.cfg,
                          self.base_params, self.kvpool, self.block_pool,
                          self.stats, index=self.prefix_index)
        self.prefill_workers.append(w)
        self.router.n = len(self.prefill_workers)

    def _remove_prefill_worker(self) -> bool:
        """Shrink the prefill pool by one, only if the LAST worker is fully
        idle — no live sessions, no admitted request holds a reference to
        it, no pending chunk work. Returns False (decision deferred) when
        the candidate is busy; the autoscaler retries next tick. Never drops
        below one worker."""
        if len(self.prefill_workers) <= 1:
            return False
        w = self.prefill_workers[-1]
        sched = self.scheduler
        if (w.sessions or w.pending_chunk_tokens
                or any(r.worker is w for r in sched.prefilling)):
            return False
        self.prefill_workers.pop()
        self.router.n = len(self.prefill_workers)
        return True

    # ------------------------------------------------------------------
    # model lifecycle (driven by repro.serving.registry.ModelRegistry)
    # ------------------------------------------------------------------
    #: top-level param subtrees that never feed a KV row: the unembed head
    #: and the final norm run strictly AFTER the last layer's KV write, so a
    #: decoder differing ONLY here produces bit-identical KV to the frozen
    #: base — the canonical PrefillShare shape of a frozen trunk + tuned head
    _KV_NEUTRAL_KEYS = ("unembed", "final_norm")

    def _relay_compatible(self, spec) -> bool:
        """May ``spec``'s decode-written KV be republished as shared prefix?
        Only if its KV path is bit-identical to the frozen base model's:
        full weights that ARE the base, or differ solely in KV-neutral
        leaves (``_KV_NEUTRAL_KEYS``). LoRA adapters perturb attention
        weights, so their KV is theirs alone. Checked once at attach time
        (weight identity is a property of the registration, not the step)."""
        if spec.full is None:
            return False
        if spec.full is self.base_params:
            return True
        tu = jax.tree_util
        if (tu.tree_structure(spec.full)
                != tu.tree_structure(self.base_params)):
            return False
        base = tu.tree_flatten_with_path(self.base_params)[0]
        for (path, lb), (_, ld) in zip(base,
                                       tu.tree_flatten_with_path(spec.full)[0]):
            key = getattr(path[0], "key", None) if path else None
            if key in self._KV_NEUTRAL_KEYS:
                continue
            if lb is not ld and not np.array_equal(np.asarray(lb),
                                                   np.asarray(ld)):
                return False
        return True

    def _attach_decoder(self, model_id: str, spec) -> None:
        """Registry hook: make ``model_id`` servable NOW (the per-model
        DecodeWorker materializes its weights lazily; the fused plane picks
        the model up at the next step boundary)."""
        dw = DecodeWorker(self.cfg, model_id, spec,
                          self.schema, self.base_params)
        dw.relay_compatible = self._relay_compatible(dw.spec)
        self.decoders[model_id] = dw

    def _detach_decoder(self, model_id: str) -> None:
        self.decoders.pop(model_id, None)

    def _rebuild_decode_plane(self) -> None:
        """Relayout the fused plane to the registry's CURRENT model set.
        Called at step boundaries only (``ModelRegistry.sync`` via the
        scheduler; plus once at construction): sequences are addressed by
        model id and every step re-derives lane indices from the new plane,
        so live sequences keep decoding bit-identically — their pages,
        positions, and sampling keys are untouched by the remap. Trace and
        dispatch counters carry across rebuilds (stats stay cumulative)."""
        if not self.fused:
            return
        old = self.decode_plane
        self.decode_plane = FusedDecodePlane(
            {mid: (self.cfg, spec)
             for mid, spec in self.models._specs.items()},
            self.kvpool, self.base_params,
            traces0=old.traces if old is not None else 0,
            dispatches0=old.dispatches if old is not None else 0)
        if old is not None:
            self.stats.plane_rebuilds += 1

    def _has_inflight(self, model_id: str) -> bool:
        """Any live work addressed to ``model_id`` (waiting / prefilling /
        decoding)? Gates drain completion and plane-lane retirement."""
        sched = self.scheduler
        return (any(r.model_id == model_id for r in sched.waiting)
                or any(r.model_id == model_id for r in sched.prefilling)
                or any(s.model_id == model_id for s in sched.active)
                or (self.swap is not None
                    and any(rec.seq.model_id == model_id
                            for rec in self.swap.records.values())))

    def _inflight_rids(self, model_id: str) -> list[int]:
        sched = self.scheduler
        parked = ([rid for rid, rec in self.swap.records.items()
                   if rec.seq.model_id == model_id]
                  if self.swap is not None else [])
        return ([r.rid for r in sched.waiting if r.model_id == model_id]
                + [r.rid for r in sched.prefilling if r.model_id == model_id]
                + [s.rid for s in sched.active if s.model_id == model_id]
                + parked)

    def _handoff_seq(self, block_table, n: int, sid: int, model_id: str,
                     params: SamplingParams, first_token: int,
                     rid: int, tokens=None, priority: int = 0) -> DecodeSeq:
        """Zero-copy handoff: block-table reference + page refcounts, with a
        page-level copy-on-write clone of a partially-filled tail page so the
        decode sequence can append privately. Raises PoolExhausted (with the
        handoff refs rolled back) if the clone page cannot be allocated.
        ``tokens`` (the prompt) rides along on the sequence so relay
        publication can key its pages by the full token stream at finish."""
        dw = self.decoders[model_id]
        HandoffChannel.check(self.schema, dw.expected_schema)
        t0 = time.perf_counter()
        bt = list(block_table)
        self.block_pool.ref(bt)
        shared, private = list(bt), []
        if n % self.page_size:
            # partial tail page is shared with the prefill session (and any
            # sibling decoder): clone it so this sequence can append.
            last = bt[-1]
            try:
                [fresh] = self.block_pool.alloc(1)
            except PoolExhausted:
                self.block_pool.unref(bt)      # roll back the handoff refs
                raise
            self.kvpool.copy_page(last, fresh)
            self.block_pool.unref([last])
            shared.pop()
            private.append(fresh)
            bt = bt[:-1] + [fresh]
            self.stats.cow_page_copies += 1
        plan = self.handoff.plan_paged(len(bt))
        # the handoff channel is priced by MEASUREMENT: the wall time of the
        # refcount + CoW work just done (the whole zero-copy handoff) feeds
        # the EWMA that plan_paged/estimate_paged_s report and the router
        # prices — replacing the old link-bandwidth fiction
        dt = time.perf_counter() - t0
        self.handoff.observe_paged(plan.bytes, dt)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += plan.bytes         # metadata only
        if self._metrics_on:
            self._h_handoff_s.observe(dt)
            self._h_handoff_b.observe(plan.bytes)
            self.metrics_registry.trace(rid).event(
                SPAN_HANDOFF, bytes=plan.bytes, seconds=dt)
        return DecodeSeq(rid, sid, model_id, bt, shared, private, n,
                         first_token, params.max_tokens, params,
                         tokens=list(tokens) if tokens is not None else [],
                         first0=first_token, priority=priority)

    def submit(self, sid: int, context_tokens, model_id: str,
               gen_tokens: int, first_token: int = 2,
               priority: int = 0) -> int:
        """DEPRECATED legacy surface: queue one greedy, fixed-length request
        against a caller-managed session id; drive with ``run``/``step`` and
        fetch via ``result``/``pop_result``. Use ``generate`` (a streaming
        ``RequestOutput`` with SamplingParams and abort) or a
        ``shared_context`` instead — this shim survives only as a
        token-identical wrapper over that path."""
        warnings.warn(
            "LocalDisaggEngine.submit() is deprecated; use "
            "engine.generate(model_id, tokens, SamplingParams(...)) or "
            "engine.shared_context(prefix).generate(...) instead",
            DeprecationWarning, stacklevel=2)
        return self._submit(sid, context_tokens, model_id,
                            SamplingParams(max_tokens=gen_tokens),
                            first_token, priority)

    def _submit(self, sid: int, context_tokens, model_id: str | None,
                params: SamplingParams, first_token: int = 2,
                priority: int = 0) -> int:
        """Queue one generation request (internal, both API surfaces).

        Chunked mode: the request enters the scheduler's admission queue and
        its prompt is prefilled in token-budget chunks interleaved with
        decode, ordered by ``priority`` under the priority policy. Legacy
        mode: whole-prompt prefill + handoff happen here, synchronously and
        in call order, so ``priority`` has no effect. ``max_tokens == 0`` is
        a prefill-only request: the prompt becomes resident (and published
        for prefix reuse) but no decode sequence is created."""
        assert self.paged, "submit/run requires the paged data plane"
        if model_id is not None:          # first-class, BEFORE any rid/pages
            self.models.check_serving(model_id)
        rid = self._next_rid
        self._next_rid += 1
        # lifecycle trace opens HERE (queued span), at the same instant the
        # rid exists; every later stage appends to it via the registry
        self.metrics_registry.start_trace(rid, model_id)
        params = self._resolve_seed(params, rid)
        tokens = [int(t) for t in np.asarray(context_tokens)]
        if self.chunked:
            self.scheduler.add(Request(
                rid=rid, sid=sid, model_id=model_id, tokens=tokens,
                gen_tokens=params.max_tokens, first_token=first_token,
                priority=priority, seq=self._next_seq, params=params))
            self._next_seq += 1
            return rid
        worker = self._pick_worker(sid, tokens)
        self.metrics_registry.trace(rid).event(SPAN_ROUTED, worker=worker.wid)
        bt, n = worker.prefill(sid, tokens)
        if params.max_tokens == 0:
            self._finish_prefill_only(rid)
            return rid
        self.scheduler.add_decode_seq(self._handoff_seq(
            bt, n, sid, model_id, params, first_token, rid, tokens=tokens,
            priority=priority))
        return rid

    # ------------------------------------------------------------------
    # request-centric API (repro.serving.api)
    # ------------------------------------------------------------------
    def generate(self, model_id: str, tokens,
                 params: SamplingParams | None = None, *, session: int | None = None,
                 priority: int = 0, first_token: int = 2,
                 stream_callback=None) -> RequestOutput:
        """Queue one generation and return its streaming ``RequestOutput``.

        ``session=None`` runs the request in an engine-owned one-shot
        session, released automatically when the request finishes (or is
        aborted) — no manual ``end_session``. Pass a ``SharedContext``'s
        session (via ``ctx.generate``) to attach to a shared prefix.
        Iterate the handle / call ``result()`` to drive the engine, or drive
        it yourself with ``run()``/``step()``."""
        self.models.check_serving(model_id)   # UnknownModelError before any
        params = SamplingParams() if params is None else params   # state
        if not priority:
            priority = params.priority    # SamplingParams carries it too
        ephemeral = session is None
        sid = self._new_context_sid() if ephemeral else session
        if not self.paged:
            params = self._resolve_seed(params, self._next_rid)
            return self._generate_dense(sid, tokens, model_id, params,
                                        first_token, ephemeral,
                                        stream_callback)
        rid = self._next_rid                      # _submit assigns this rid
        params = self._resolve_seed(params, rid)  # handle sees the real seed
        out = RequestOutput(self, rid, sid, model_id, params)
        if stream_callback is not None:
            out.add_callback(stream_callback)
        self._requests[rid] = out
        if ephemeral:
            self._ephemeral_sids[rid] = sid
        try:
            got = self._submit(sid, tokens, model_id, params, first_token,
                               priority)
        except Exception:
            # eager-mode prefill can raise (PoolExhausted) after the handle
            # was registered: unwind so retries don't leak orphan handles
            self._requests.pop(rid, None)
            self._ephemeral_sids.pop(rid, None)
            raise
        assert got == rid
        return out

    def shared_context(self, prefix_tokens=(), *,
                       prefill: bool = True) -> SharedContext:
        """Open a first-class shared prefix (see ``repro.serving.api``):
        one prefilled context that multiple ``ctx.generate(model_id, tail)``
        calls attach to — the paper's execution pattern as the API's main
        verb. Use as a context manager; exit releases the pages."""
        return SharedContext(self, prefix_tokens, prefill=prefill)

    def abort(self, request) -> bool:
        """Cancel a request at any lifecycle stage. Accepts a
        ``RequestOutput`` or a raw request id. Returns True if the request
        was still live (False: already finished, already aborted, unknown).

        Queued: removed before any pages are touched. Prefilling (including
        held under pool backpressure): its chunk-granular allocation is
        reclaimed — cached prefix pages return to the LRU cache, partially
        written tail pages are dropped. Decoding: its handoff refs and
        private pages are released. In every case the pool's free-page count
        returns exactly to its pre-request baseline."""
        rid = request.request_id if isinstance(request, RequestOutput) \
            else int(request)
        if rid in self._results or rid in self._fetched \
                or rid in self._aborted:
            return False
        sched = self.scheduler
        for r in sched.waiting:                    # queued: nothing held yet
            if r.rid == rid:
                sched.waiting.remove(r)
                self._on_request_aborted(rid)
                return True
        for r in sched.prefilling:                 # mid-chunk / held / stalled
            if r.rid != rid:
                continue
            sched.prefilling.remove(r)
            if r.sibling_bt is not None:
                self.block_pool.unref(r.sibling_bt)   # drop the sibling pin
            elif r.committed:
                pass       # the session owns the allocation now; pages stay
            else:
                r.worker.mgr.abandon(r.alloc)
                r.worker.pending_chunk_tokens -= r.n - r.done
            self._on_request_aborted(rid)
            return True
        if self.swap is not None and rid in self.swap.records:
            # parked in the swap tier: shared refs released, still-resident
            # swapped rows freed, host copy discarded — free pages return
            # exactly to the pre-request baseline (revoked rows already
            # belong to their new owners and are not touched)
            self.swap.abort(rid)
            self._on_request_aborted(rid)
            return True
        for s in sched.active:                     # decoding
            if s.rid != rid:
                continue
            if s.remaining <= 0:
                return False   # generation already complete, merely awaiting
                               # the next step's reap — not abortable
            sched.active.remove(s)
            self.block_pool.unref(s.shared_blocks)
            self.block_pool.drop(s.private_blocks)
            self._on_request_aborted(rid)
            return True
        return False

    @staticmethod
    def _resolve_seed(params: SamplingParams, rid: int) -> SamplingParams:
        """``seed=None`` -> a distinct engine-assigned per-request seed (the
        rid), so N sampled fan-outs over one prompt give N different draws;
        an explicit seed passes through untouched for cross-run
        reproducibility. Idempotent once resolved."""
        if params.seed is not None:
            return params
        return dataclasses.replace(params, seed=rid)

    def _new_context_sid(self) -> int:
        sid = self._next_ctx_sid
        self._next_ctx_sid += 1
        return sid

    def _prefill_context(self, sid: int, tokens) -> None:
        """Make ``tokens`` resident for session ``sid`` (SharedContext
        warm-up). Eager mode prefills synchronously; chunked mode drives the
        scheduler until the prefill-only request completes."""
        assert self.paged, "shared contexts require the paged data plane"
        rid = self._submit(sid, tokens, None, SamplingParams(max_tokens=0))
        while rid not in self._results:
            self.scheduler.step()
        self.pop_result(rid)                       # empty marker array

    def _finish_prefill_only(self, rid: int) -> None:
        self._results[rid] = np.zeros(0, np.int32)
        self._on_request_done(rid, FINISH_LENGTH)

    def _on_request_done(self, rid: int, reason: str) -> None:
        # terminal trace span: "aborted" for aborts (at ANY lifecycle
        # stage — queued / prefilling / held / decoding all funnel here),
        # "finished" with the reason otherwise
        self.metrics_registry.trace(rid).close(reason)
        out = self._requests.pop(rid, None)        # engine-side handle ref:
        if out is not None:                        # dropped once finished
            out._mark_finished(reason)
        sid = self._ephemeral_sids.pop(rid, None)
        if sid is not None:
            self.end_session(sid)                  # one-shot session cleanup

    def _on_request_aborted(self, rid: int) -> None:
        self._aborted.add(rid)
        self._on_request_done(rid, FINISH_ABORT)

    def _generate_dense(self, sid, tokens, model_id, params, first_token,
                        ephemeral, stream_callback=None) -> RequestOutput:
        """Dense-fallback generate (SSM/hybrid archs): synchronous, but the
        same RequestOutput contract (params honoured, tokens streamed to
        callbacks, finish reason set)."""
        out = RequestOutput(self, self._next_rid, sid, model_id, params)
        self._next_rid += 1
        if stream_callback is not None:
            out.add_callback(stream_callback)
        toks, reason = self._invoke_dense(sid, tokens, model_id, params,
                                          first_token)
        for t in toks:
            out._push(int(t))
        if self._metrics_on and out.ttft is not None:
            self._h_ttft.observe(out.ttft)
            for gap in out.inter_token_latencies():
                self._h_itl.observe(gap)
        out._mark_finished(reason)
        if ephemeral:
            self.end_session(sid)
        return out

    def run(self) -> None:
        """Drive the scheduler until every queued request finishes: each step
        packs (one decode token per active sequence) + (prefill chunks under
        the token budget) — see serving/scheduler/."""
        self.scheduler.run()

    def step(self) -> None:
        """One scheduler step (benchmarks/tests interleave arrivals)."""
        self.scheduler.step()

    def _grow_tail_pages(self, seqs: list[DecodeSeq]) -> None:
        page = self.page_size
        for s in seqs:                       # grow private tail pages
            if s.pos >= len(s.block_table) * page:
                [fresh] = self.block_pool.alloc(1)
                s.block_table.append(fresh)
                s.private_blocks.append(fresh)

    def decode_step(self, seqs: list[DecodeSeq]) -> None:
        """Advance every active sequence — across ALL decode models — one
        token, sampled per each request's SamplingParams (temperature=0:
        exact greedy). Fused mode (default): ONE jitted vmapped forward per
        step per distinct decode config (one total here, every decoder shares
        the engine config). fused=False: the per-model dispatch loop.

        Token bookkeeping is centralized here: streaming pushes to the
        request handles, and eos/stop detection that zeroes ``remaining`` so
        the scheduler retires the sequence (freeing its budget slot and,
        via ``_finish``, its pages) on the next step — variable-length
        finishes mid-flight."""
        if not seqs:
            return
        self._grow_tail_pages(seqs)
        if self.decode_plane is not None:
            before = self.decode_plane.dispatches
            nxt = self.decode_plane.step(seqs)
            self.stats.decode_dispatches += self.decode_plane.dispatches - before
        else:
            nxt = np.zeros(len(seqs), np.int32)
            by_model: dict[str, list] = {}
            for i, s in enumerate(seqs):
                by_model.setdefault(s.model_id, []).append(i)
            for mid, idx in by_model.items():
                nxt[idx] = self._batched_step(mid, [seqs[i] for i in idx])
        metrics_on = self._metrics_on      # ONE branch per token when off —
        for i, s in enumerate(seqs):       # no metric objects touched at all
            t = int(nxt[i])
            s.out.append(t)
            s.next_token = t
            s.pos += 1
            s.remaining -= 1
            out = self._requests.get(s.rid)
            if out is not None:
                out._push(t)
                if metrics_on:
                    # TTFT/ITL histograms + trace spans use the SAME
                    # timestamps RequestOutput just recorded at push time,
                    # so exported percentiles are exactly what a streaming
                    # client observes
                    times = out.token_times
                    if len(times) == 1:
                        self._h_ttft.observe(times[0] - out.submit_time)
                        self.metrics_registry.trace(s.rid).event(
                            SPAN_FIRST_TOKEN, t=times[0])
                    else:
                        self._h_itl.observe(times[-1] - times[-2])
                        self.metrics_registry.trace(s.rid).event(
                            SPAN_TOKEN, t=times[-1])
            reason = s.params.is_stop(t)
            if reason is not None:
                s.finish_reason = reason
                s.remaining = 0                    # retired next reap
        # one ENGINE step regardless of mode, so decode_steps (and
        # decode_batch_mean) mean the same thing fused and legacy
        self.stats.decode_steps += 1
        self.stats.decode_tokens += len(seqs)
        if metrics_on:
            self._h_batch.observe(len(seqs))

    def _batched_step(self, mid: str, seqs: list[DecodeSeq]) -> np.ndarray:
        """One per-model jitted forward (legacy fused=False dispatch unit);
        returns the sampled next tokens aligned with ``seqs``.
        ``decode_step`` owns all bookkeeping and has already grown the tail
        pages for the whole batch."""
        # pow2-bucket the table width: the padded columns are masked by pos,
        # so this is token-identical while bounding jit retraces at O(log)
        npages = next_pow2(max(len(s.block_table) for s in seqs))
        bt = np.zeros((len(seqs), npages), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.block_table)] = s.block_table
        toks = jnp.asarray([s.next_token for s in seqs], jnp.int32)
        pos = jnp.asarray([s.pos for s in seqs], jnp.int32)
        cache = self.kvpool.make_decode_cache(bt)
        nxt, new_cache = self.decoders[mid].step(toks, pos, cache,
                                                 *sampling_arrays(seqs))
        self.kvpool.absorb_decode_cache(new_cache)
        self.stats.decode_dispatches += 1
        return np.asarray(nxt)

    def _relay_publish(self, s: DecodeSeq) -> set:
        """Publish a FINISHED sequence's resident KV into the radix tree,
        keyed by its full token stream (prompt ⧺ first decode input ⧺
        generated tokens bar the last, whose KV was never written) — the
        zero-copy handoff run in reverse. Returns the set of page ids the
        tree adopted; ``_finish`` keeps those (unref -> CACHED, evictable,
        reusable by ANY model) and hard-drops the rest as before. Only
        relay-compatible decoders publish (KV bit-identical to the frozen
        base — ``_relay_compatible``); everything else, plus aborts (which
        never reach here), behaves exactly as without relay."""
        if not (self.relay and s.tokens and s.out):
            return set()
        dw = self.decoders.get(s.model_id)
        if dw is None or not dw.relay_compatible:
            self.stats.relay_skipped += 1
            return set()
        # position p holds the KV of the token INPUT at p: prompt tokens at
        # 0..n-1, the handoff's first decode input at n, out[:-1] after —
        # len(stream) == s.pos, and only full pages are indexable
        stream = list(s.tokens) + [s.first0] + [int(t) for t in s.out[:-1]]
        full = s.pos // self.page_size
        adopted = set(self.prefix_index.insert_pages(
            stream, s.block_table[:full], provenance="relay"))
        if adopted:
            self.stats.relay_publishes += 1
            self.stats.relay_pages_published += len(adopted)
        return adopted

    def _finish(self, s: DecodeSeq) -> None:
        self._results[s.rid] = np.asarray(s.out, np.int32)
        adopted = self._relay_publish(s)
        self.block_pool.unref(s.shared_blocks)   # freed only w/ last holder
        if adopted:
            # relay-published pages stay resident (CACHED, LRU-evictable,
            # tree-served); duplicates/partial tail are dropped as before
            self.block_pool.unref([b for b in s.private_blocks
                                   if b in adopted])
            self.block_pool.drop([b for b in s.private_blocks
                                  if b not in adopted])
        else:
            self.block_pool.drop(s.private_blocks)   # generated KV: private
        self._on_request_done(s.rid, s.finish_reason or FINISH_LENGTH)

    # ------------------------------------------------------------------
    def invoke(self, sid: int, context_tokens, model_id: str,
               gen_tokens: int, first_token: int = 2) -> np.ndarray:
        """DEPRECATED legacy surface: one blocking greedy invocation against
        a caller-managed session id. Use ``generate(...).result()`` or a
        ``shared_context`` instead; this shim stays token-identical to that
        path (asserted in tests/test_api.py)."""
        warnings.warn(
            "LocalDisaggEngine.invoke() is deprecated; use "
            "engine.generate(model_id, tokens, SamplingParams(...)).result() "
            "or engine.shared_context(prefix).generate(...) instead",
            DeprecationWarning, stacklevel=2)
        params = SamplingParams(max_tokens=gen_tokens)
        if not self.paged:
            toks, _ = self._invoke_dense(sid, context_tokens, model_id,
                                         params, first_token)
            return toks
        rid = self._submit(sid, context_tokens, model_id, params, first_token)
        self.run()
        return self.pop_result(rid)

    def _check_rid(self, rid: int) -> None:
        if rid in self._results:
            return
        if rid in self._fetched:
            raise KeyError(
                f"request {rid}: result was already fetched via pop_result()")
        if rid in self._aborted:
            raise KeyError(
                f"request {rid}: aborted — no result was produced (streamed "
                f"tokens, if any, live on its RequestOutput handle)")
        if 0 <= rid < self._next_rid:
            raise KeyError(
                f"request {rid}: submitted but not finished — still waiting, "
                f"prefilling, or decoding; drive the engine with run()/step()")
        raise KeyError(
            f"request {rid}: unknown request id (ids 0..{self._next_rid - 1} "
            f"have been issued)")

    def result(self, rid: int) -> np.ndarray:
        """Return the finished output for ``rid`` WITHOUT consuming it —
        repeated calls return the same array; the entry is retained until an
        explicit ``pop_result``. Raises a KeyError naming the rid and its
        fetch state (pending / already-popped / unknown) instead of a bare
        lookup failure."""
        self._check_rid(rid)
        return self._results[rid]

    def pop_result(self, rid: int) -> np.ndarray:
        """Fetch and release the finished output for ``rid`` (frees the
        engine-side copy; a second pop raises a descriptive KeyError)."""
        self._check_rid(rid)
        self._fetched.add(rid)
        return self._results.pop(rid)

    def _invoke_dense(self, sid, context_tokens, model_id, params,
                      first_token):
        self.models.check_serving(model_id)
        worker = self._pick_worker(
            sid, [int(t) for t in np.asarray(context_tokens)])
        sc = worker.prefill(sid, context_tokens)
        dw = self.decoders[model_id]
        HandoffChannel.check(self.schema, dw.expected_schema)
        cache = transfer_cache(sc.cache)               # decode-side copy
        plan = self.handoff.plan(sc.n_tokens)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += plan.bytes
        return dw.generate(cache, sc.n_tokens, first_token, params)

    def end_session(self, sid: int):
        for w in self.prefill_workers:
            w.end_session(sid)
