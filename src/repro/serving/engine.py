"""Small-scale REAL-JAX disaggregated engine (integration-test twin of the
simulator).

Runs actual models on CPU: a prefill worker hosting the frozen base model
(per-session cache, incrementally extended — §3.3 partial prefill), a decode
pool of task-specific cache-conditioned decoders, and a cache-handoff step
that copies the base cache to the decode side with a schema check. Metrics
(prefix hit tokens, handoff bytes) use the same CacheManager bookkeeping as
the simulator, so the event-level logic is validated against real tensors.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefillshare import base_prefill, cache_schema
from repro.kvcache.handoff import HandoffChannel, transfer_cache
from repro.kvcache.manager import CacheManager
from repro.models import forward


@dataclass
class SessionCache:
    cache: object
    n_tokens: int
    capacity: int


@dataclass
class EngineStats:
    prefill_tokens_computed: int = 0
    prefill_tokens_reused: int = 0
    handoffs: int = 0
    handoff_bytes: int = 0

    @property
    def hit_ratio(self):
        tot = self.prefill_tokens_computed + self.prefill_tokens_reused
        return self.prefill_tokens_reused / tot if tot else 0.0


class PrefillWorker:
    """Hosts the frozen base model; one incrementally-extended cache/session."""

    def __init__(self, cfg: ModelConfig, base_params, *, capacity: int = 512,
                 mgr_blocks: int = 4096, block_size: int = 16):
        self.cfg = cfg
        self.base_params = base_params
        self.schema = cache_schema(cfg, base_params, capacity)
        self.sessions: dict[int, SessionCache] = {}
        self.mgr = CacheManager(cfg, mgr_blocks, block_size)
        self.stats = EngineStats()

    def prefill(self, sid: int, tokens: np.ndarray) -> SessionCache:
        """Ensure the session cache covers ``tokens``; compute only the tail."""
        tokens = np.asarray(tokens)
        n = len(tokens)
        sc = self.sessions.get(sid)
        alloc = self.mgr.acquire(tokens.tolist())      # block-level metrics
        self.mgr.commit(tokens.tolist(), alloc)
        self.mgr.release(alloc)
        if sc is None:
            out, cache = base_prefill(
                self.cfg, self.base_params, jnp.asarray(tokens)[None],
                cache_len=max(self.schema.cache_len, n))
            sc = SessionCache(cache, n, max(self.schema.cache_len, n))
            self.stats.prefill_tokens_computed += n
        else:
            assert n > sc.n_tokens, "context is append-only"
            new = tokens[sc.n_tokens:]
            _, cache = base_prefill(
                self.cfg, self.base_params, jnp.asarray(new)[None],
                cache_len=sc.capacity, cache=sc.cache,
                pos=jnp.array([sc.n_tokens], jnp.int32))
            self.stats.prefill_tokens_computed += len(new)
            self.stats.prefill_tokens_reused += sc.n_tokens
            sc = SessionCache(cache, n, sc.capacity)
        self.sessions[sid] = sc
        return sc

    def end_session(self, sid: int):
        self.sessions.pop(sid, None)


class DecodeWorker:
    """Hosts ONE task-specific decode module (cache-conditioned)."""

    def __init__(self, cfg: ModelConfig, model_id: str, dec_params,
                 expected_schema):
        self.cfg = cfg
        self.model_id = model_id
        self.dec_params = dec_params
        self.expected_schema = expected_schema

    def generate(self, cache, start_pos: int, first_token: int,
                 n_tokens: int) -> np.ndarray:
        cfg = self.cfg
        B = 1
        pos = jnp.array([start_pos], jnp.int32)
        tok = jnp.array([first_token], jnp.int32)
        out = []
        for _ in range(n_tokens):
            logits, cache, _ = forward(cfg, self.dec_params, tok[:, None],
                                       cache=cache, pos=pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(tok[0]))
            pos = pos + 1
        return np.asarray(out, np.int32)


class LocalDisaggEngine:
    """Proxy + prefill worker + heterogeneous decode pool (Appendix B.1)."""

    def __init__(self, cfg: ModelConfig, base_params, decoders: dict,
                 *, capacity: int = 512):
        self.cfg = cfg
        self.prefill = PrefillWorker(cfg, base_params, capacity=capacity)
        self.handoff = HandoffChannel(cfg)
        self.decoders = {
            mid: DecodeWorker(cfg, mid, params, self.prefill.schema)
            for mid, params in decoders.items()}
        self.stats = self.prefill.stats

    def invoke(self, sid: int, context_tokens, model_id: str,
               gen_tokens: int, first_token: int = 2) -> np.ndarray:
        """One agent invocation: shared/partial prefill -> handoff ->
        selective decode (paper §3.3 execution pipeline)."""
        sc = self.prefill.prefill(sid, context_tokens)
        dw = self.decoders[model_id]
        HandoffChannel.check(self.prefill.schema, dw.expected_schema)
        cache = transfer_cache(sc.cache)               # decode-side copy
        plan = self.handoff.plan(sc.n_tokens)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += plan.bytes
        return dw.generate(cache, sc.n_tokens, first_token, gen_tokens)

    def end_session(self, sid: int):
        self.prefill.end_session(sid)
