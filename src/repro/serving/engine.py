"""Small-scale REAL-JAX disaggregated engine on a paged KV data plane.

Runs actual models: a pool of prefill workers hosting the frozen base model
(selected per-session by the PrefillRouter), one shared physical
``PagedKVPool`` whose pages back every allocation the per-worker
``CacheManager``s make, and a set of task-specific decode workers that run
CONTINUOUS-BATCH greedy decode over the pool.

The run loop is owned by the chunked-prefill scheduler
(``repro.serving.scheduler``): with ``chunked=True`` each step packs one
decode token per active sequence plus as many prefill chunks as fit a
per-step token budget (chunks attend to the cached prefix straight from the
pool pages via ``flash_prefill_paged`` — no dense gather); with the default
eager mode ``submit`` prefills whole prompts synchronously (the historical
behaviour, kept bit-identical) and the scheduler steps decode only.

Data plane (pure global-attention archs, the paper's operating point):
  - prefill: the router picks a worker; its CacheManager matches the longest
    cached prefix (radix, page-granular) and allocates physical pages for the
    tail; ``base_prefill_paged`` gathers the prefix KV out of the pool,
    extends it with the frozen base model, and scatters the fresh rows back
    into the pages via the ``paged_write`` kernel. The allocation is held for
    the whole session (released in ``end_session``), so a live session's
    pages are never evictable.
  - handoff: ZERO-COPY. The decode side receives a block-table reference and
    takes a refcount on every page; a partially-filled tail page is cloned
    first (page-level copy-on-write) so concurrent decoders can append
    privately. ``handoff_bytes`` counts only the block-table metadata.
  - decode: all active sequences (across sessions AND decode models sharing
    this config) advance one token per engine step in ONE fused, jitted,
    vmapped forward over model-stacked decoder params (serving/decode.py;
    ``fused=False`` restores the per-model dispatch loop), using the paged
    decode-attention step (Pallas kernel on TPU, jnp gather twin elsewhere),
    with generated KV appended to freshly allocated private pages. The pool's
    page buffers are donated into the jitted step on TPU so pages update in
    place. Pages are freed only when the last holder (prefill session or
    decode sequence) releases them.

Archs with non-KV sequence state (SSM/recurrent/hybrid/enc-dec) fall back to
the dense per-session path (``paged=False``), preserving the state-handoff
semantics validated in tests/test_engine_ssm.py.

Prefix-hit accounting comes from the SAME CacheManager bookkeeping the
simulator uses (``Allocation.cached_tokens``), so engine and simulator stats
share one accounting path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

import time

from repro.configs.base import ModelConfig
from repro.core.prefillshare import (base_prefill, base_prefill_paged,
                                     cache_schema)
from repro.kvcache.blocks import BlockPool, PoolExhausted
from repro.kvcache.handoff import HandoffChannel, transfer_cache
from repro.kvcache.manager import CacheManager
from repro.kvcache.paged import PagedKVPool
from repro.models import forward
from repro.serving.backpressure import ThroughputEWMA
from repro.serving.decode import FusedDecodePlane
from repro.serving.router import PrefillRouter
from repro.serving.scheduler import (ChunkedScheduler, Request,
                                     SchedulerConfig)


@dataclass
class SessionCache:
    """Dense-path session state (SSM/hybrid/enc-dec fallback)."""
    cache: object
    n_tokens: int
    capacity: int
    alloc: object = None          # held until end_session (residency == refs)


@dataclass
class PagedSession:
    alloc: object                 # CacheManager Allocation, held for lifetime
    block_table: list             # physical page per logical page
    n_tokens: int
    tokens: list                  # context (for sibling-submit fast path)


@dataclass
class DecodeSeq:
    """One in-flight generation: a block-table reference into the shared
    pool (zero-copy handoff) plus private pages for generated tokens."""
    rid: int
    sid: int
    model_id: str
    block_table: list
    shared_blocks: list           # refcounted prefix pages (unref on finish)
    private_blocks: list          # CoW tail + generated pages (drop on finish)
    pos: int                      # tokens currently in the cache
    next_token: int               # token whose KV the next step writes
    remaining: int
    out: list = field(default_factory=list)


@dataclass
class EngineStats:
    prefill_tokens_computed: int = 0
    prefill_tokens_reused: int = 0
    handoffs: int = 0
    handoff_bytes: int = 0
    cow_page_copies: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0
    decode_dispatches: int = 0    # jitted decode forwards issued

    @property
    def hit_ratio(self):
        tot = self.prefill_tokens_computed + self.prefill_tokens_reused
        return self.prefill_tokens_reused / tot if tot else 0.0

    @property
    def decode_batch_mean(self):
        return self.decode_tokens / self.decode_steps if self.decode_steps else 0.0


# ======================================================================
# Prefill workers


class PrefillWorker:
    """Paged prefill worker: frozen base model + per-worker CacheManager
    (own radix index) over the engine's SHARED physical page pool."""

    def __init__(self, wid: int, cfg: ModelConfig, base_params,
                 kvpool: PagedKVPool, block_pool: BlockPool,
                 stats: EngineStats):
        self.wid = wid
        self.cfg = cfg
        self.base_params = base_params
        self.kvpool = kvpool
        self.mgr = CacheManager(cfg, block_pool.num_blocks,
                                block_pool.block_size, pool=block_pool)
        self.sessions: dict[int, PagedSession] = {}
        self.stats = stats
        self.backlog_s = 0.0      # router load signal (estimated work issued)
        self.last_decay_t = time.monotonic()   # backlog decay clock
        self.ewma = ThroughputEWMA()       # measured prefill s/token
        self.pending_chunk_tokens = 0      # admitted-but-uncomputed (chunked)

    def prefill(self, sid: int, tokens) -> tuple[list, int]:
        """Ensure pool pages cover ``tokens``; compute only the uncached
        tail. Returns (block_table, n_tokens)."""
        tokens = [int(t) for t in np.asarray(tokens)]
        n = len(tokens)
        sc = self.sessions.get(sid)
        if sc is not None and sc.tokens == tokens:
            # sibling submit of the identical context (e.g. several decode
            # models fanning out over one turn): the session's pages already
            # hold it — no acquire, no recompute, no fresh partial page.
            self.mgr.record_hit(n)             # same accounting path
            self.stats.prefill_tokens_reused += n
            return list(sc.block_table), n
        alloc = self.mgr.acquire(tokens)
        n_cached = alloc.cached_tokens
        bt = list(alloc.blocks)
        if n_cached < n:
            new = jnp.asarray(tokens[n_cached:], jnp.int32)[None]
            t0 = time.perf_counter()
            out = base_prefill_paged(self.cfg, self.base_params, new,
                                     pool=self.kvpool, block_table=bt,
                                     n_cached=n_cached)
            jax.block_until_ready(out)
            self.ewma.observe(n - n_cached, time.perf_counter() - t0)
        self.mgr.commit(tokens, alloc)
        if sc is not None:
            self.mgr.release(sc.alloc)     # swap, don't drop: new alloc holds
        self.sessions[sid] = PagedSession(alloc, bt, n, tokens)
        self.stats.prefill_tokens_computed += n - n_cached
        self.stats.prefill_tokens_reused += n_cached
        self.backlog_s += (n - n_cached) * self.ewma.s_per_token
        return bt, n

    def end_session(self, sid: int):
        sc = self.sessions.pop(sid, None)
        if sc is not None:
            self.mgr.release(sc.alloc)     # pages -> CACHED (LRU, reusable)


class DensePrefillWorker:
    """Dense fallback: one incrementally-extended cache per session (archs
    whose sequence state is not paged KV). The page-level CacheManager still
    runs for accounting, and — unlike the seed — the allocation is HELD for
    the session lifetime so residency matches the refcounts."""

    def __init__(self, cfg: ModelConfig, base_params, *, capacity: int = 512,
                 mgr_blocks: int = 4096, block_size: int = 16,
                 stats: EngineStats | None = None):
        self.cfg = cfg
        self.base_params = base_params
        self.schema = cache_schema(cfg, base_params, capacity)
        self.capacity = capacity
        self.sessions: dict[int, SessionCache] = {}
        self.mgr = CacheManager(cfg, mgr_blocks, block_size)
        self.stats = stats if stats is not None else EngineStats()
        self.backlog_s = 0.0
        self.last_decay_t = time.monotonic()
        self.ewma = ThroughputEWMA()
        self.pending_chunk_tokens = 0

    def prefill(self, sid: int, tokens) -> SessionCache:
        tokens = np.asarray(tokens)
        n = len(tokens)
        sc = self.sessions.get(sid)
        alloc = self.mgr.acquire(tokens.tolist())      # block-level metrics
        self.mgr.commit(tokens.tolist(), alloc)
        t0 = time.perf_counter()
        if sc is None:
            _, cache = base_prefill(
                self.cfg, self.base_params, jnp.asarray(tokens)[None],
                cache_len=max(self.capacity, n))
            jax.block_until_ready(cache)
            self.ewma.observe(n, time.perf_counter() - t0)
            new = SessionCache(cache, n, max(self.capacity, n), alloc)
            self.stats.prefill_tokens_computed += n
        else:
            assert n > sc.n_tokens, "context is append-only"
            fresh = tokens[sc.n_tokens:]
            _, cache = base_prefill(
                self.cfg, self.base_params, jnp.asarray(fresh)[None],
                cache_len=sc.capacity, cache=sc.cache,
                pos=jnp.array([sc.n_tokens], jnp.int32))
            jax.block_until_ready(cache)
            self.ewma.observe(len(fresh), time.perf_counter() - t0)
            self.stats.prefill_tokens_computed += len(fresh)
            self.stats.prefill_tokens_reused += sc.n_tokens
            self.mgr.release(sc.alloc)
            new = SessionCache(cache, n, sc.capacity, alloc)
        self.sessions[sid] = new
        self.backlog_s += n * self.ewma.s_per_token
        return new

    def end_session(self, sid: int):
        sc = self.sessions.pop(sid, None)
        if sc is not None and sc.alloc is not None:
            self.mgr.release(sc.alloc)


# ======================================================================
# Decode


class DecodeWorker:
    """Hosts ONE task-specific decode module (cache-conditioned).

    Paged mode: ``step`` advances every assigned sequence by one token in a
    single batched forward (continuous batching over the shared pool).
    Dense mode: ``generate`` is the legacy B=1 loop over a private cache.
    """

    def __init__(self, cfg: ModelConfig, model_id: str, dec_params,
                 expected_schema):
        self.cfg = cfg
        self.model_id = model_id
        self.dec_params = dec_params
        self.expected_schema = expected_schema
        self._step = None

    # ---- paged continuous batching ----
    def step(self, tokens, pos, cache):
        """One batched greedy step: feed ``tokens`` (B,) at positions ``pos``
        (B,), paged cache attached; returns (next_tokens (B,), new_cache)."""
        if self._step is None:
            cfg = self.cfg

            def _step(params, toks, pos, cache):
                logits, new_cache, _ = forward(cfg, params, toks[:, None],
                                               cache=cache, pos=pos)
                return jnp.argmax(logits, -1).astype(jnp.int32), new_cache

            # jit keyed on (B, npages) shapes; retraces only when the batch
            # composition or table width changes. The cache (pool pages +
            # block tables) is donated where donation is honoured, so the
            # step appends KV in place; make_decode_cache/absorb_decode_cache
            # are the donation-aware pair around this call.
            donate = (3,) if jax.default_backend() == "tpu" else ()
            self._step = jax.jit(_step, donate_argnums=donate)
        return self._step(self.dec_params, tokens, pos, cache)

    # ---- dense fallback ----
    def generate(self, cache, start_pos: int, first_token: int,
                 n_tokens: int) -> np.ndarray:
        cfg = self.cfg
        pos = jnp.array([start_pos], jnp.int32)
        tok = jnp.array([first_token], jnp.int32)
        out = []
        for _ in range(n_tokens):
            logits, cache, _ = forward(cfg, self.dec_params, tok[:, None],
                                       cache=cache, pos=pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(tok[0]))
            pos = pos + 1
        return np.asarray(out, np.int32)


# ======================================================================
# Engine


class LocalDisaggEngine:
    """Proxy + prefill worker pool + heterogeneous decode pool over one
    shared paged KV plane (Appendix B.1, upgraded to the §3.3 pipeline)."""

    def __init__(self, cfg: ModelConfig, base_params, decoders: dict, *,
                 capacity: int = 512, paged: bool | None = None,
                 num_pages: int = 1024, page_size: int = 16,
                 n_prefill_workers: int = 1, router_policy: str = "pinned",
                 chunked: bool = False, token_budget: int = 256,
                 chunk_size: int = 64, sched_policy: str = "fcfs",
                 fused: bool | None = None):
        self.cfg = cfg
        self.base_params = base_params
        self.page_size = page_size
        self.stats = EngineStats()
        self.chunked = chunked
        self.paged = PagedKVPool.supports(cfg) if paged is None else paged
        if self.paged and not PagedKVPool.supports(cfg):
            raise ValueError(f"{cfg.name}: arch not eligible for paged plane")
        self.schema = cache_schema(cfg, base_params, capacity)
        self.handoff = HandoffChannel(cfg)
        self.router = PrefillRouter(n_prefill_workers, router_policy)
        if self.paged:
            self.block_pool = BlockPool(num_pages, page_size)
            self.kvpool = PagedKVPool(cfg, num_pages, page_size)
            self.prefill_workers = [
                PrefillWorker(i, cfg, base_params, self.kvpool,
                              self.block_pool, self.stats)
                for i in range(n_prefill_workers)]
        else:
            self.block_pool = None
            self.kvpool = None
            self.prefill_workers = [
                DensePrefillWorker(cfg, base_params, capacity=capacity,
                                   block_size=page_size, stats=self.stats)
                for _ in range(n_prefill_workers)]
        self.prefill = self.prefill_workers[0]        # 1-worker convenience
        self.decoders = {
            mid: DecodeWorker(cfg, mid, params, self.schema)
            for mid, params in decoders.items()}
        # fused cross-model decode (serving.decode): stack the decoder param
        # pytrees and advance every sequence of every model in ONE vmapped,
        # jitted forward per step. Default on the paged plane; fused=False
        # keeps the per-model dispatch loop (comparison/regression path).
        self.fused = self.paged if fused is None else fused
        assert not (self.fused and not self.paged), \
            "fused decode requires the paged data plane"
        self.decode_plane = FusedDecodePlane(
            {mid: (cfg, params) for mid, params in decoders.items()},
            self.kvpool) if self.fused else None
        self.scheduler = ChunkedScheduler(
            self, SchedulerConfig(token_budget=token_budget,
                                  chunk_size=chunk_size,
                                  policy=sched_policy))
        self._results: dict[int, np.ndarray] = {}
        self._fetched: set[int] = set()
        self._next_rid = 0
        self._next_seq = 0

    #: half-life of the issued-work router signal, in seconds of WALL TIME.
    #: Decay must be a function of elapsed time, not of pick count — a
    #: per-pick multiplicative decay makes the load signal depend on arrival
    #: rate (two bursts a second apart would see completely different
    #: backlogs), which tests/test_router.py pins as a regression.
    BACKLOG_HALFLIFE_S = 0.25

    # ------------------------------------------------------------------
    def _pick_worker(self, sid: int, now: float | None = None):
        # Prefill here is synchronous, so there is no literal queue; the
        # routing signal is recency-weighted issued work plus (in chunked
        # mode) the admitted-but-uncomputed chunk backlog, both priced at
        # the worker's MEASURED s/token EWMA. The issued-work term decays
        # exponentially in ELAPSED TIME (half-life above), which keeps
        # least_loaded balancing while preventing spillover from permanently
        # migrating pinned sessions off an idle worker just because its
        # lifetime total is ahead — and, unlike the old per-pick halving,
        # makes the signal invariant to how often the router is consulted.
        now = time.monotonic() if now is None else now
        for w in self.prefill_workers:
            dt = now - w.last_decay_t
            if dt > 0:
                w.backlog_s *= 0.5 ** (dt / self.BACKLOG_HALFLIFE_S)
                w.last_decay_t = now
        backlogs = [w.backlog_s + w.ewma.backlog_seconds(w.pending_chunk_tokens)
                    for w in self.prefill_workers]
        return self.prefill_workers[self.router.pick(sid, now, backlogs)]

    def _handoff_seq(self, block_table, n: int, sid: int, model_id: str,
                     gen_tokens: int, first_token: int, rid: int) -> DecodeSeq:
        """Zero-copy handoff: block-table reference + page refcounts, with a
        page-level copy-on-write clone of a partially-filled tail page so the
        decode sequence can append privately. Raises PoolExhausted (with the
        handoff refs rolled back) if the clone page cannot be allocated."""
        dw = self.decoders[model_id]
        HandoffChannel.check(self.schema, dw.expected_schema)
        bt = list(block_table)
        self.block_pool.ref(bt)
        shared, private = list(bt), []
        if n % self.page_size:
            # partial tail page is shared with the prefill session (and any
            # sibling decoder): clone it so this sequence can append.
            last = bt[-1]
            try:
                [fresh] = self.block_pool.alloc(1)
            except PoolExhausted:
                self.block_pool.unref(bt)      # roll back the handoff refs
                raise
            self.kvpool.copy_page(last, fresh)
            self.block_pool.unref([last])
            shared.pop()
            private.append(fresh)
            bt = bt[:-1] + [fresh]
            self.stats.cow_page_copies += 1
        plan = self.handoff.plan_paged(len(bt))
        self.stats.handoffs += 1
        self.stats.handoff_bytes += plan.bytes         # metadata only
        return DecodeSeq(rid, sid, model_id, bt, shared, private, n,
                         first_token, gen_tokens)

    def submit(self, sid: int, context_tokens, model_id: str,
               gen_tokens: int, first_token: int = 2,
               priority: int = 0) -> int:
        """Queue one generation request; drive with ``run`` (or ``step``).
        Returns a request id.

        Chunked mode: the request enters the scheduler's admission queue and
        its prompt is prefilled in token-budget chunks interleaved with
        decode, ordered by ``priority`` under the priority policy. Legacy
        mode: whole-prompt prefill + handoff happen here, synchronously and
        in call order, so ``priority`` has no effect."""
        assert self.paged, "submit/run requires the paged data plane"
        rid = self._next_rid
        self._next_rid += 1
        tokens = [int(t) for t in np.asarray(context_tokens)]
        if self.chunked:
            self.scheduler.add(Request(
                rid=rid, sid=sid, model_id=model_id, tokens=tokens,
                gen_tokens=gen_tokens, first_token=first_token,
                priority=priority, seq=self._next_seq))
            self._next_seq += 1
            return rid
        worker = self._pick_worker(sid)
        bt, n = worker.prefill(sid, tokens)
        self.scheduler.add_decode_seq(self._handoff_seq(
            bt, n, sid, model_id, gen_tokens, first_token, rid))
        return rid

    def run(self) -> None:
        """Drive the scheduler until every queued request finishes: each step
        packs (one decode token per active sequence) + (prefill chunks under
        the token budget) — see serving/scheduler/."""
        self.scheduler.run()

    def step(self) -> None:
        """One scheduler step (benchmarks/tests interleave arrivals)."""
        self.scheduler.step()

    def _grow_tail_pages(self, seqs: list[DecodeSeq]) -> None:
        page = self.page_size
        for s in seqs:                       # grow private tail pages
            if s.pos >= len(s.block_table) * page:
                [fresh] = self.block_pool.alloc(1)
                s.block_table.append(fresh)
                s.private_blocks.append(fresh)

    def decode_step(self, seqs: list[DecodeSeq]) -> None:
        """Advance every active sequence — across ALL decode models — one
        greedy token. Fused mode (default): ONE jitted vmapped forward per
        step per distinct decode config (one total here, every decoder shares
        the engine config). fused=False: the per-model dispatch loop."""
        if not seqs:
            return
        self._grow_tail_pages(seqs)
        if self.decode_plane is not None:
            before = self.decode_plane.dispatches
            nxt = self.decode_plane.step(seqs)
            self.stats.decode_dispatches += self.decode_plane.dispatches - before
            for i, s in enumerate(seqs):
                s.out.append(int(nxt[i]))
                s.next_token = int(nxt[i])
                s.pos += 1
                s.remaining -= 1
        else:
            by_model: dict[str, list] = {}
            for s in seqs:
                by_model.setdefault(s.model_id, []).append(s)
            for mid, group in by_model.items():
                self._batched_step(mid, group)
        # one ENGINE step regardless of mode, so decode_steps (and
        # decode_batch_mean) mean the same thing fused and legacy
        self.stats.decode_steps += 1
        self.stats.decode_tokens += len(seqs)

    def _batched_step(self, mid: str, seqs: list[DecodeSeq]) -> None:
        """One per-model jitted forward (legacy fused=False dispatch unit).
        ``decode_step`` owns step/token accounting and has already grown the
        tail pages for the whole batch."""
        npages = max(len(s.block_table) for s in seqs)
        bt = np.zeros((len(seqs), npages), np.int32)
        for i, s in enumerate(seqs):
            bt[i, :len(s.block_table)] = s.block_table
        toks = jnp.asarray([s.next_token for s in seqs], jnp.int32)
        pos = jnp.asarray([s.pos for s in seqs], jnp.int32)
        cache = self.kvpool.make_decode_cache(bt)
        nxt, new_cache = self.decoders[mid].step(toks, pos, cache)
        self.kvpool.absorb_decode_cache(new_cache)
        nxt = np.asarray(nxt)
        for i, s in enumerate(seqs):
            s.out.append(int(nxt[i]))
            s.next_token = int(nxt[i])
            s.pos += 1
            s.remaining -= 1
        self.stats.decode_dispatches += 1

    def _finish(self, s: DecodeSeq) -> None:
        self._results[s.rid] = np.asarray(s.out, np.int32)
        self.block_pool.unref(s.shared_blocks)   # freed only w/ last holder
        self.block_pool.drop(s.private_blocks)   # generated KV: not reusable

    # ------------------------------------------------------------------
    def invoke(self, sid: int, context_tokens, model_id: str,
               gen_tokens: int, first_token: int = 2) -> np.ndarray:
        """One agent invocation: shared/partial prefill -> handoff ->
        selective decode (paper §3.3 execution pipeline). Drains every
        pending sequence (batching this request with any prior submits)."""
        if not self.paged:
            return self._invoke_dense(sid, context_tokens, model_id,
                                      gen_tokens, first_token)
        rid = self.submit(sid, context_tokens, model_id, gen_tokens,
                          first_token)
        self.run()
        return self.pop_result(rid)

    def _check_rid(self, rid: int) -> None:
        if rid in self._results:
            return
        if rid in self._fetched:
            raise KeyError(
                f"request {rid}: result was already fetched via pop_result()")
        if 0 <= rid < self._next_rid:
            raise KeyError(
                f"request {rid}: submitted but not finished — still waiting, "
                f"prefilling, or decoding; drive the engine with run()/step()")
        raise KeyError(
            f"request {rid}: unknown request id (ids 0..{self._next_rid - 1} "
            f"have been issued)")

    def result(self, rid: int) -> np.ndarray:
        """Return the finished output for ``rid`` WITHOUT consuming it —
        repeated calls return the same array; the entry is retained until an
        explicit ``pop_result``. Raises a KeyError naming the rid and its
        fetch state (pending / already-popped / unknown) instead of a bare
        lookup failure."""
        self._check_rid(rid)
        return self._results[rid]

    def pop_result(self, rid: int) -> np.ndarray:
        """Fetch and release the finished output for ``rid`` (frees the
        engine-side copy; a second pop raises a descriptive KeyError)."""
        self._check_rid(rid)
        self._fetched.add(rid)
        return self._results.pop(rid)

    def _invoke_dense(self, sid, context_tokens, model_id, gen_tokens,
                      first_token):
        worker = self._pick_worker(sid)
        sc = worker.prefill(sid, context_tokens)
        dw = self.decoders[model_id]
        HandoffChannel.check(self.schema, dw.expected_schema)
        cache = transfer_cache(sc.cache)               # decode-side copy
        plan = self.handoff.plan(sc.n_tokens)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += plan.bytes
        return dw.generate(cache, sc.n_tokens, first_token, gen_tokens)

    def end_session(self, sid: int):
        for w in self.prefill_workers:
            w.end_session(sid)
