"""Metrics-driven elastic prefill:decode scaling (control-loop policy).

PPD ("Not All Prefills Are Equal", PAPERS.md) shows the prefill:decode
resource split must track the workload mix — a prefill-heavy phase (long
prompts, short generations) starves TTFT when decode hoards workers, and a
decode-heavy phase (chatty generations) starves ITL when prefill does. The
production-stack router/KEDA pattern (SNIPPETS.md) scales prefill and decode
pods independently off exactly the metrics this repo now exports
(serving/metrics.py): queue backlog, slot occupancy, free-memory headroom,
latency percentiles.

This module is the POLICY, deliberately split from actuation:

  - ``decide(cfg, signals) -> ResizeDecision`` is a PURE function — no
    clocks, no engine references — so its invariants are property-testable
    (tests/test_autoscale.py): it never scales decode below in-flight
    demand, never leaves the [min, max] prefill band, moves at most one
    worker per tick, and under constant signals the fixed point is reached
    and held (hysteresis: the shrink threshold sits well below the grow
    threshold, so a backlog between them changes nothing).
  - ``Autoscaler`` wraps it with the time-domain guards (evaluation
    interval, post-resize cooldown) the pure function must not know about.

Two consumers:
  - the SIMULATOR (serving/simulator.py, ``ServingConfig.autoscale``):
    ``prefill_delta``/``decode_delta`` shift workers between the prefill and
    decode pools under a fixed chip budget — the diurnal two-phase scenario
    in benchmarks/autoscale_sim.py gates that this beats every static split
    on p95 TTFT.
  - the REAL ENGINE (``LocalDisaggEngine(autoscale=...)``): prefill_delta
    adds/removes real ``PrefillWorker``s at step boundaries (the PR-5 model
    churn pattern — new workers share the pool, radix tree, and stats);
    decode_delta maps onto the scheduler's decode admission reserve, since
    the fused decode plane is one step, not a worker count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["AutoscaleConfig", "AutoscaleSignals", "ResizeDecision",
           "decide", "Autoscaler"]


@dataclass(frozen=True)
class AutoscaleConfig:
    """Control-loop thresholds. The grow/shrink pairs are HYSTERESIS bands:
    grow fires above the high mark, shrink below the low mark, and anything
    between is the converged dead zone — that gap is what makes the loop
    settle instead of oscillate under constant load."""
    min_prefill: int = 1
    max_prefill: int = 8
    min_decode: int = 1
    max_decode: int = 8
    #: per-decode-worker concurrent-sequence capacity (simulator: its
    #: max_decode_batch; engine: the scheduler token budget) — used for the
    #: never-below-in-flight-demand guard on decode shrink
    decode_slots: int = 64
    #: per-prefill-worker backlog seconds that trigger growing/shrinking
    #: the prefill pool
    backlog_high_s: float = 0.5
    backlog_low_s: float = 0.05
    #: decode occupancy (active sequences / total decode slots) marks
    occupancy_high: float = 0.85
    occupancy_low: float = 0.30
    #: pool free-page fraction below which decode headroom takes priority
    free_page_low: float = 0.10
    #: optional TTFT p95 target: overrides the backlog dead zone and forces
    #: a prefill grow while the measured window exceeds it, and blocks
    #: prefill shrink until the window drops below 70% of it (None: off)
    ttft_target_s: float | None = None
    #: optional joint worker budget (fixed chip fleet). When the fleet is at
    #: budget, a grow on one side must be funded by a shrink on the other —
    #: ``decide`` never emits an unfunded grow past the budget. (None: the
    #: pools scale independently, the cloud-elastic mode.)
    total_budget: int | None = None
    #: seconds between policy evaluations (Autoscaler)
    interval_s: float = 1.0
    #: extra evaluation intervals to hold after an applied resize, letting
    #: the previous decision's effect reach the signals before acting again
    cooldown_intervals: int = 2
    #: consecutive evaluations that must all vote for a PURE shrink before
    #: one is applied (Autoscaler). Grows and funded shifts act immediately
    #: — they protect latency — but an instantaneous backlog sampled between
    #: arrival bursts reads as idle, so giving capacity back needs sustained
    #: evidence or the loop sheds workers it is about to want back.
    shrink_patience: int = 3


@dataclass
class AutoscaleSignals:
    """One sample of the registry-derived inputs the policy consumes."""
    prefill_backlog_tokens: int
    prefill_backlog_s: float       # backlog tokens priced at measured s/tok
    decode_occupancy: float        # active sequences / total decode slots
    free_page_frac: float          # pool free pages / total pages
    ttft_p95_s: float              # NaN when the window is empty
    itl_p95_s: float               # NaN when the window is empty
    n_prefill: int
    n_decode: int
    inflight_decode: int           # sequences currently decoding


@dataclass(frozen=True)
class ResizeDecision:
    prefill_delta: int = 0         # -1 | 0 | +1 (one worker per tick, max)
    decode_delta: int = 0
    reason: str = "steady"

    def __bool__(self):
        return bool(self.prefill_delta or self.decode_delta)


def _decode_can_shrink(cfg: AutoscaleConfig, sig: AutoscaleSignals) -> bool:
    """Shrinking decode is legal only if the REMAINING capacity still covers
    every in-flight sequence — the never-scale-below-demand invariant."""
    return (sig.n_decode > cfg.min_decode
            and (sig.n_decode - 1) * cfg.decode_slots >= sig.inflight_decode)


def decide(cfg: AutoscaleConfig, sig: AutoscaleSignals) -> ResizeDecision:
    """Pure resize policy: one look at the signals, at most one worker of
    movement. Two regimes:

    - ``total_budget`` set (fixed fleet): idle hardware is sunk cost, so the
      fleet always runs AT budget — an under-budget pool fills up first, and
      thereafter every move is a balanced (+1,-1) SHIFT between the pools.
      Pure shrink never fires: shedding a worker from a fixed fleet only
      parks capacity.
    - ``total_budget`` None (cloud-elastic): pools grow under pressure and
      give capacity back when idle — the scale-to-zero economics of
      independently deployed pods.
    """
    per_worker_backlog = sig.prefill_backlog_s / max(sig.n_prefill, 1)
    # TTFT bundles prefill queueing AND one decode step — a decode-side ITL
    # blowup inflates it too. Judge PREFILL by TTFT net of the decode step,
    # or a decode stall would read as prefill pressure and the loop would
    # move workers in exactly the wrong direction.
    itl = 0.0 if math.isnan(sig.itl_p95_s) else sig.itl_p95_s
    queue_ttft = sig.ttft_p95_s - itl
    ttft_over = (cfg.ttft_target_s is not None
                 and not math.isnan(sig.ttft_p95_s)
                 and queue_ttft > cfg.ttft_target_s)
    backlog_busy = per_worker_backlog > cfg.backlog_high_s
    prefill_busy = backlog_busy or ttft_over
    decode_pressed = (sig.decode_occupancy >= cfg.occupancy_high
                      or sig.free_page_frac <= cfg.free_page_low)

    if cfg.total_budget is not None:
        # fill spare budget first — toward whichever pool is pressed, decode
        # winning ties (its pressure compounds through KV residency)
        if sig.n_prefill + sig.n_decode < cfg.total_budget:
            if decode_pressed and sig.n_decode < cfg.max_decode:
                return ResizeDecision(0, +1, "fill budget: grow decode")
            if sig.n_prefill < cfg.max_prefill:
                return ResizeDecision(+1, 0, "fill budget: grow prefill")
            if sig.n_decode < cfg.max_decode:
                return ResizeDecision(0, +1, "fill budget: grow decode")
            return ResizeDecision(reason="held: both pools at max")
        # at budget: balanced shifts only. Decode pressure first (it
        # compounds — overflowing KV inflates every step), funded from
        # prefill only when prefill has no REAL token backlog; latency
        # signals can't tell the pools apart, the backlog can.
        if decode_pressed and sig.n_decode < cfg.max_decode \
                and not backlog_busy and sig.n_prefill > cfg.min_prefill:
            return ResizeDecision(-1, +1, "decode pressure: shift from prefill")
        if prefill_busy and sig.n_prefill < cfg.max_prefill \
                and not decode_pressed and sig.n_decode > cfg.min_decode \
                and _decode_can_shrink(cfg, sig):
            return ResizeDecision(+1, -1, "prefill backlog: shift from decode")
        return ResizeDecision()

    # -- cloud-elastic regime --------------------------------------------
    # 1) decode under pressure: occupancy or page headroom critical
    if decode_pressed and sig.n_decode < cfg.max_decode:
        return ResizeDecision(0, +1, "decode pressure: grow decode")

    # 2) prefill backlogged (or TTFT target blown): grow prefill
    if prefill_busy and sig.n_prefill < cfg.max_prefill:
        return ResizeDecision(+1, 0, "prefill backlog: grow prefill")

    # 3) reclaim idle capacity (shrink side of the hysteresis bands). The
    #    instantaneous backlog of an idle-LOOKING pool can be zero between
    #    arrival bursts, so when a TTFT target is set the latency window —
    #    which integrates over the bursts — must also be comfortably under
    #    target before prefill gives a worker back.
    ttft_healthy = (cfg.ttft_target_s is None
                    or math.isnan(sig.ttft_p95_s)
                    or queue_ttft < 0.7 * cfg.ttft_target_s)
    if per_worker_backlog < cfg.backlog_low_s and ttft_healthy \
            and sig.n_prefill > cfg.min_prefill:
        return ResizeDecision(-1, 0, "prefill idle: shrink prefill")
    if sig.decode_occupancy < cfg.occupancy_low \
            and sig.free_page_frac > cfg.free_page_low \
            and _decode_can_shrink(cfg, sig):
        return ResizeDecision(0, -1, "decode idle: shrink decode")

    return ResizeDecision()


class Autoscaler:
    """Stateful wrapper: rate-limits ``decide`` to ``interval_s`` and holds
    ``cooldown_intervals`` after an applied resize so the previous move's
    effect shows up in the signals before the next one. Accepts ``True`` as
    shorthand for a default ``AutoscaleConfig``."""

    def __init__(self, cfg: AutoscaleConfig | bool = True):
        self.cfg = AutoscaleConfig() if cfg is True else cfg
        self._next_eval_t: float | None = None
        self._shrink_votes = 0          # consecutive pure-shrink decisions
        self.decisions: list[ResizeDecision] = []    # applied (nonzero) log

    def tick(self, sig: AutoscaleSignals, now: float) -> ResizeDecision:
        cfg = self.cfg
        if self._next_eval_t is not None and now < self._next_eval_t:
            return ResizeDecision(reason="held: interval")
        d = decide(cfg, sig)
        # debounce pure shrinks: only a run of shrink_patience consecutive
        # shrink votes releases capacity (grows/shifts reset the run)
        if d and d.prefill_delta <= 0 and d.decode_delta <= 0:
            self._shrink_votes += 1
            if self._shrink_votes < cfg.shrink_patience:
                self._next_eval_t = now + cfg.interval_s
                return ResizeDecision(reason=f"held: shrink vote "
                                      f"{self._shrink_votes}/"
                                      f"{cfg.shrink_patience}")
        else:
            self._shrink_votes = 0
        hold = cfg.interval_s * (1 + cfg.cooldown_intervals if d else 1)
        self._next_eval_t = now + hold
        if d:
            self._shrink_votes = 0
            self.decisions.append(d)
        return d
