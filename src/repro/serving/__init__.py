from repro.serving.costmodel import CostModel
from repro.serving.decode import FusedDecodePlane, StackedDecoders
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import PATTERNS, Session, make_sessions
