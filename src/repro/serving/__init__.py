from repro.serving.api import (FINISH_ABORT, FINISH_EOS, FINISH_LENGTH,
                               FINISH_STOP, RequestOutput, SamplingParams,
                               SharedContext, UnknownModelError)
from repro.serving.autoscale import (AutoscaleConfig, AutoscaleSignals,
                                     Autoscaler, ResizeDecision)
from repro.serving.costmodel import CostModel
from repro.serving.decode import FusedDecodePlane, StackedDecoders
from repro.serving.metrics import (MetricsRegistry, RequestTrace,
                                   lint_prometheus)
from repro.serving.registry import (DecodeModelSpec, LoRAAdapter,
                                    ModelRegistry)
from repro.serving.simulator import ServingConfig, Simulator
from repro.serving.workload import PATTERNS, Session, make_sessions

__all__ = [
    "FINISH_ABORT", "FINISH_EOS", "FINISH_LENGTH", "FINISH_STOP",
    "RequestOutput", "SamplingParams", "SharedContext", "UnknownModelError",
    "AutoscaleConfig", "AutoscaleSignals", "Autoscaler", "ResizeDecision",
    "CostModel", "FusedDecodePlane", "StackedDecoders",
    "MetricsRegistry", "RequestTrace", "lint_prometheus",
    "DecodeModelSpec", "LoRAAdapter", "ModelRegistry",
    "ServingConfig", "Simulator", "PATTERNS", "Session", "make_sessions",
]
