"""Prefix-aware routing policies (paper §3.3 + beyond).

The paper routes "requests with the same shared prefix ... to a consistent
prefill worker WHENEVER POSSIBLE" — leaving the locality-vs-load tradeoff
unspecified. Policies:

  pinned       — paper behaviour: session -> hash(worker). Max prefix
                 locality; hot sessions can queue behind a busy worker.
  least_loaded — ignore locality, pick the shortest queue. Max load balance;
                 every migration costs a full re-prefill on the new worker.
  spillover    — pinned, but if the pinned worker's backlog exceeds
                 ``spill_threshold`` seconds, fall back to the least-loaded
                 worker (paying the one-time prefix recompute there, which
                 then seeds ITS cache). The "whenever possible" made precise.
  prefix_aware — price the request's expected COLD work (prompt tokens minus
                 the longest cached-prefix hit, in seconds at each worker's
                 measured rate) alongside the backlog, and pick the worker
                 minimizing expected completion time. A long prefix hit makes
                 a request nearly free — the chunked scheduler skips the
                 cached pages entirely — so a busy worker holding the prefix
                 beats an idle cold one, and under the engine-global radix
                 tree (hit length worker-independent) the policy degrades to
                 least_loaded with a home-worker tie-break. PPD's "Not All
                 Prefills Are Equal" observation, applied to routing. The
                 ``match_len`` walk makes no provenance distinction, so
                 relay-published pages (decode-written KV adopted at finish)
                 price exactly like prefill-cached ones: a pipeline
                 consumer whose prompt embeds a producer's output is near
                 free, only its tail is cold (tests/test_relay.py).

``benchmarks`` comparison: tests/test_router.py asserts the qualitative
ordering (spillover >= pinned throughput under skewed load, pinned >= others
on hit ratio).
"""
from __future__ import annotations

POLICIES = ("pinned", "least_loaded", "spillover", "prefix_aware")


class PrefillRouter:
    def __init__(self, n_workers: int, policy: str = "pinned",
                 spill_threshold_s: float = 0.5):
        assert policy in POLICIES, policy
        self.n = n_workers
        self.policy = policy
        self.spill = spill_threshold_s

    def pick(self, sid: int, now: float, backlogs, cold_s=None,
             handoff_s: float = 0.0) -> int:
        """backlogs: per-worker estimated seconds of queued work.
        cold_s: per-worker estimated seconds to prefill THIS request's
        uncached tokens there (None when the caller has no prefix estimate —
        ``prefix_aware`` then falls back to pure backlog).
        handoff_s: MEASURED expected handoff cost appended to every
        candidate's completion-time estimate (the EWMA of real zero-copy
        handoffs — ``HandoffChannel.estimate_paged_s`` — not the old
        bandwidth fiction). In-process it is worker-independent, so today it
        calibrates the estimate without changing the argmin; once cross-mesh
        page transport lands (ROADMAP) it becomes per-candidate and starts
        steering placement.

        The engine prices all signals with a MEASURED per-worker s/token
        EWMA (serving.backpressure.ThroughputEWMA) over both eager issued
        work and, in chunked mode, the admitted-but-uncomputed chunk
        backlog — so routing compares real seconds, not a hardcoded
        per-token constant, and a request's cost shrinks with its expected
        prefix-hit length."""
        home = sid % self.n
        if self.policy == "pinned":
            return home
        if self.policy == "prefix_aware":
            # expected completion time = queue wait + own cold prefill +
            # measured handoff; ties (e.g. idle fleet, global tree => equal
            # hit) stay home so per-session fast paths keep their locality
            total = [backlogs[i] + (cold_s[i] if cold_s is not None else 0.0)
                     + handoff_s
                     for i in range(self.n)]
            return min(range(self.n), key=lambda i: (total[i], i != home))
        least = min(range(self.n), key=lambda i: backlogs[i])
        if self.policy == "least_loaded":
            return least
        # spillover
        if backlogs[home] - backlogs[least] > self.spill:
            return least
        return home
