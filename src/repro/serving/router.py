"""Prefix-aware routing policies (paper §3.3 + beyond).

The paper routes "requests with the same shared prefix ... to a consistent
prefill worker WHENEVER POSSIBLE" — leaving the locality-vs-load tradeoff
unspecified. Policies:

  pinned       — paper behaviour: session -> hash(worker). Max prefix
                 locality; hot sessions can queue behind a busy worker.
  least_loaded — ignore locality, pick the shortest queue. Max load balance;
                 every migration costs a full re-prefill on the new worker.
  spillover    — pinned, but if the pinned worker's backlog exceeds
                 ``spill_threshold`` seconds, fall back to the least-loaded
                 worker (paying the one-time prefix recompute there, which
                 then seeds ITS cache). The "whenever possible" made precise.

``benchmarks`` comparison: tests/test_router.py asserts the qualitative
ordering (spillover >= pinned throughput under skewed load, pinned >= others
on hit ratio).
"""
from __future__ import annotations

POLICIES = ("pinned", "least_loaded", "spillover")


class PrefillRouter:
    def __init__(self, n_workers: int, policy: str = "pinned",
                 spill_threshold_s: float = 0.5):
        assert policy in POLICIES, policy
        self.n = n_workers
        self.policy = policy
        self.spill = spill_threshold_s

    def pick(self, sid: int, now: float, backlogs) -> int:
        """backlogs: per-worker estimated seconds of queued work.

        The engine prices this signal with a MEASURED per-worker s/token
        EWMA (serving.backpressure.ThroughputEWMA) over both eager issued
        work and, in chunked mode, the admitted-but-uncomputed chunk
        backlog — so spillover thresholds compare real seconds, not a
        hardcoded per-token constant."""
        home = sid % self.n
        if self.policy == "pinned":
            return home
        least = min(range(self.n), key=lambda i: backlogs[i])
        if self.policy == "least_loaded":
            return least
        # spillover
        if backlogs[home] - backlogs[least] > self.spill:
            return least
        return home
