"""Fused cross-model decode plane: stacked decoders, ONE vmapped step.

The paper's decode pool hosts many task-specific modules over one shared
prefill KV pool — but a per-model dispatch loop pays one jitted forward (and
one retrace key) per decode model per engine step. Since every decode module
sharing a ``ModelConfig`` is structurally identical (full fine-tunes and
LoRA merges alike), their param pytrees stack on a leading model axis
(``core.lora.stack_params``), and one ``vmap`` over that axis advances EVERY
active sequence of EVERY model in a single jitted forward per step.

Layout per step (``StackedDecoders.step``):
  - sequences are bucketed per model into an (M, Bmax) grid, padded with fake
    rows whose block tables point at the sentinel page 0 (never allocated, so
    their garbage writes cannot alias live KV) — M stays constant across the
    run (a model with zero active sequences keeps its lane), so lane count
    never contributes retraces;
  - block-table width is bucketed to the next power of two, so jit retraces
    stop scaling with prompt length (growth by one page within a bucket
    reuses the trace);
  - the pool's page buffers enter the jitted step as ONE donated-on-TPU
    pytree (``PagedKVPool.decode_state``), so pages update in place instead
    of the per-step functional pool copy;
  - inside the step, each model lane runs the unchanged paged decode forward
    over a lane-local view of the pool; the ONE fresh KV row each real
    sequence wrote is gathered back out of its lane and scattered into the
    shared pool — bit-exact, because pages are private per sequence (the
    lane-local copies are dead after the gather and fuse away).

Per-request SamplingParams execute INSIDE the fused step (serving/
sampling.py): each real sequence's logits row is gathered out of its lane
and sampled with a PRNG key folded from (seed, position) — batch-packing-
invariant — while temperature=0 rows take the exact argmax graph, keeping
greedy outputs asserted identical to the per-model loop
(tests/test_fused_decode.py); the per-model path remains available as
``LocalDisaggEngine(fused=False)`` for comparison.

On TPU the vmapped lanes lower the paged-attention Pallas kernel through its
batching rule; off-TPU the pure-jnp gather twin vmaps natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import stack_params
from repro.models import forward
from repro.serving.sampling import sample_step


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1): the block-table width bucket."""
    return 1 << max(0, int(n) - 1).bit_length()


def sampling_arrays(seqs):
    """Per-sequence (temperature, top_k, top_p, seed) arrays for a decode
    batch, aligned with ``seqs`` (values, not trace keys — changing a
    request's SamplingParams never retraces the step), plus a host-side
    ``greedy_only`` flag. The flag IS a (binary) trace key: an all-greedy
    batch — the default, and every pre-API workload — dispatches an
    argmax-only step with none of the sampling graph's sort/softmax/draw
    dead weight on the decode hot path."""
    temps = np.asarray([s.params.temperature for s in seqs], np.float32)
    top_ks = np.asarray([s.params.top_k for s in seqs], np.int32)
    top_ps = np.asarray([s.params.top_p for s in seqs], np.float32)
    seeds = np.asarray([s.params.seed for s in seqs], np.int32)
    greedy_only = bool((temps <= 0.0).all())
    return (jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(top_ps),
            jnp.asarray(seeds), greedy_only)


def group_by_config(decoders):
    """Partition ``{model_id: (cfg, params)}`` into fusable groups: models
    sharing an identical ModelConfig stack into one StackedDecoders lane set;
    each distinct config costs one dispatch per step."""
    groups: dict = {}
    for mid, (cfg, params) in decoders.items():
        groups.setdefault(cfg, {})[mid] = params
    return groups


class StackedDecoders:
    """All decode modules of ONE ModelConfig, stacked for the fused step."""

    def __init__(self, cfg, decoders: dict, kvpool):
        assert decoders, "need at least one decode module"
        self.cfg = cfg
        self.kvpool = kvpool
        self.page_size = kvpool.page_size
        self.model_ids = sorted(decoders)            # stable model-axis order
        self.index = {mid: m for m, mid in enumerate(self.model_ids)}
        self.stacked = stack_params([decoders[mid] for mid in self.model_ids])
        self.traces = 0                              # jit retraces (tests)
        self.dispatches = 0                          # jitted-step invocations
        self._step = self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        cfg, n_full, page = self.cfg, self.kvpool.n_full, self.page_size
        wire = self.kvpool.wire_decode_cache

        def fused(stacked, state, toks, pos, bts, seq_m, seq_b,
                  temps, top_ks, top_ps, seeds, greedy_only):
            # Python body runs once per trace: count retraces here.
            self.traces += 1

            def lane(params, t, p, bt):
                cache = wire(state, bt, n_full)      # state: shared, unbatched
                logits, new_cache, _ = forward(cfg, params, t[:, None],
                                               cache=cache, pos=p)
                return logits, new_cache

            lg_all, caches = jax.vmap(lane)(stacked, toks, pos, bts)
            # Each real sequence wrote exactly ONE row, at (page, slot) named
            # by its own block table — gather those rows out of the lane-local
            # pool copies and scatter them into the shared state. Pages are
            # private per sequence (sentinel page 0 absorbs fake-row writes),
            # so indices never collide and the merge is bit-exact.
            pg_all = jnp.take_along_axis(bts, (pos // page)[..., None],
                                         axis=2)[..., 0]            # (M, Bmax)
            pg = pg_all[seq_m, seq_b]
            slot = (pos % page)[seq_m, seq_b]                       # (N,)
            new_groups = {}
            for g, st in state["groups"].items():
                ko = caches["groups"][g]["k_pages"]  # (M, n_full, P, pg, H, D)
                vo = caches["groups"][g]["v_pages"]
                rk = jnp.moveaxis(ko[seq_m, :, pg, slot], 0, 1)  # (n_full,N,H,D)
                rv = jnp.moveaxis(vo[seq_m, :, pg, slot], 0, 1)
                new_groups[g] = {"k": st["k"].at[:, pg, slot].set(rk),
                                 "v": st["v"].at[:, pg, slot].set(rv)}
            new_tail = []
            for i, st in enumerate(state["tail"]):
                ko = caches["tail"][i]["k_pages"]    # (M, P, page, H, D)
                vo = caches["tail"][i]["v_pages"]
                new_tail.append(
                    {"k": st["k"].at[pg, slot].set(ko[seq_m, pg, slot]),
                     "v": st["v"].at[pg, slot].set(vo[seq_m, pg, slot])})
            # per-request sampling, INSIDE the fused step (no extra
            # dispatch): each real sequence's logits row is gathered out of
            # its lane and sampled with a key folded from (seed, position) —
            # batch-packing-invariant; temperature=0 rows are exact argmax
            # (serving/sampling.py), keeping greedy outputs bit-identical.
            # greedy_only is STATIC: an all-greedy batch traces an
            # argmax-only step, paying none of the sampling graph.
            lg = lg_all[seq_m, seq_b]                               # (N, V)
            if greedy_only:
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)
            else:
                nxt = sample_step(lg, pos[seq_m, seq_b], temps, top_ks,
                                  top_ps, seeds)
            return nxt, {"groups": new_groups, "tail": new_tail}

        # donate the pool buffers (arg 1) where donation is honoured, so the
        # fused step appends KV in place — mirrors kvcache.paged.copy_page
        donate = (1,) if jax.default_backend() == "tpu" else ()
        return jax.jit(fused, donate_argnums=donate, static_argnums=(11,))

    # ------------------------------------------------------------------
    def step(self, seqs) -> np.ndarray:
        """Advance every sequence (any mix of this group's models) one token
        in ONE jitted forward — sampled per each sequence's SamplingParams
        (greedy when temperature=0); returns next tokens aligned with
        ``seqs``. Tail pages must already cover position ``pos``."""
        M, page = len(self.model_ids), self.page_size
        counts = [0] * M
        coords = []
        for s in seqs:
            m = self.index[s.model_id]
            coords.append((m, counts[m]))
            counts[m] += 1
        bmax = max(counts)
        npages = next_pow2(max(len(s.block_table) for s in seqs))
        toks = np.zeros((M, bmax), np.int32)
        pos = np.zeros((M, bmax), np.int32)
        bts = np.zeros((M, bmax, npages), np.int32)   # pad = sentinel page 0
        for s, (m, b) in zip(seqs, coords):
            toks[m, b] = s.next_token
            pos[m, b] = s.pos
            bts[m, b, :len(s.block_table)] = s.block_table
        seq_m = jnp.asarray([m for m, _ in coords], jnp.int32)
        seq_b = jnp.asarray([b for _, b in coords], jnp.int32)
        nxt, new_state = self._step(self.stacked, self.kvpool.decode_state(),
                                    jnp.asarray(toks), jnp.asarray(pos),
                                    jnp.asarray(bts), seq_m, seq_b,
                                    *sampling_arrays(seqs))
        self.kvpool.absorb_decode_state(new_state)
        self.dispatches += 1
        return np.asarray(nxt)


class FusedDecodePlane:
    """Routes sequences to their config group's StackedDecoders: one jitted
    dispatch per engine step per distinct decode ModelConfig (ONE total when
    every decode module shares the engine's config — the paper's setting)."""

    def __init__(self, decoders, kvpool):
        """decoders: {model_id: (cfg, params)}."""
        self.groups = [StackedDecoders(cfg, members, kvpool)
                       for cfg, members in group_by_config(decoders).items()]
        self._group_of = {mid: g for g in self.groups for mid in g.model_ids}

    @property
    def traces(self) -> int:
        return sum(g.traces for g in self.groups)

    @property
    def dispatches(self) -> int:
        return sum(g.dispatches for g in self.groups)

    def step(self, seqs) -> np.ndarray:
        """One engine decode step; returns next tokens aligned with seqs."""
        nxt = np.zeros(len(seqs), np.int32)
        for g in self.groups:
            idx = [i for i, s in enumerate(seqs) if self._group_of[s.model_id] is g]
            if idx:
                nxt[idx] = g.step([seqs[i] for i in idx])
        return nxt
