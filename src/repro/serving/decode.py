"""Fused cross-model decode plane: stacked decoders, ONE vmapped step.

The paper's decode pool hosts many task-specific modules over one shared
prefill KV pool — but a per-model dispatch loop pays one jitted forward (and
one retrace key) per decode model per engine step. Since every decode module
sharing a ``ModelConfig`` is structurally identical (full fine-tunes and
LoRA merges alike), their param pytrees stack on a leading model axis
(``core.lora.stack_params``), and one ``vmap`` over that axis advances EVERY
active sequence of EVERY model in a single jitted forward per step.

Weight layout per group (``DecodeModelSpec.group_key``):
  - FULL specs stack complete param pytrees: every leaf is (M, ...).
  - LORA specs stack ONLY the low-rank A/B factors (``stack_lora_params``);
    the frozen base weights enter the step once, UNBATCHED, and each lane
    merges ``W + scale * A[m] @ B[m]`` inside the jitted step right before
    its forward — the decode plane stores one base copy + M adapter sets
    instead of M materialized full models (Eq. 9 on the weight side), and
    the merge is asserted bit-identical to pre-merged ``lora_apply``
    decoders (tests/test_registry.py).

Layout per step (``StackedDecoders.step``):
  - sequences are bucketed per model into an (M, Bmax) grid, padded with fake
    rows whose block tables point at the sentinel page 0 (never allocated, so
    their garbage writes cannot alias live KV). M is the group's CURRENT
    model count: the registry (serving/registry.py) rebuilds the plane at
    step boundaries on churn, and every sequence's lane index is re-derived
    from its model id per step, so hot (un)registration remaps lanes without
    touching any live sequence's pages;
  - block-table width is bucketed to the next power of two, so jit retraces
    stop scaling with prompt length (growth by one page within a bucket
    reuses the trace);
  - the pool's page buffers enter the jitted step as ONE donated-on-TPU
    pytree (``PagedKVPool.decode_state``), so pages update in place instead
    of the per-step functional pool copy;
  - inside the step, each model lane runs the unchanged paged decode forward
    over a lane-local view of the pool; the ONE fresh KV row each real
    sequence wrote is gathered back out of its lane and scattered into the
    shared pool — bit-exact, because pages are private per sequence (the
    lane-local copies are dead after the gather and fuse away).

Per-request SamplingParams execute INSIDE the fused step (serving/
sampling.py): each real sequence's logits row is gathered out of its lane
and sampled with a PRNG key folded from (seed, position) — batch-packing-
invariant — while temperature=0 rows take the exact argmax graph, keeping
greedy outputs asserted identical to the per-model loop
(tests/test_fused_decode.py); the per-model path remains available as
``LocalDisaggEngine(fused=False)`` for comparison.

On TPU the vmapped lanes lower the paged-attention Pallas kernel through its
batching rule; off-TPU the pure-jnp gather twin vmaps natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import lora_apply, stack_lora_params, stack_params
from repro.models import forward
from repro.serving.sampling import sample_step


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1): the block-table width bucket."""
    return 1 << max(0, int(n) - 1).bit_length()


def sampling_arrays(seqs):
    """Per-sequence (temperature, top_k, top_p, seed) arrays for a decode
    batch, aligned with ``seqs`` (values, not trace keys — changing a
    request's SamplingParams never retraces the step), plus a host-side
    ``greedy_only`` flag. The flag IS a (binary) trace key: an all-greedy
    batch — the default, and every pre-API workload — dispatches an
    argmax-only step with none of the sampling graph's sort/softmax/draw
    dead weight on the decode hot path."""
    temps = np.asarray([s.params.temperature for s in seqs], np.float32)
    top_ks = np.asarray([s.params.top_k for s in seqs], np.int32)
    top_ps = np.asarray([s.params.top_p for s in seqs], np.float32)
    seeds = np.asarray([s.params.seed for s in seqs], np.int32)
    greedy_only = bool((temps <= 0.0).all())
    return (jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(top_ps),
            jnp.asarray(seeds), greedy_only)


def group_specs(specs):
    """Partition ``{model_id: (cfg, DecodeModelSpec)}`` into fusable groups:
    models sharing an identical ModelConfig AND weight-layout bucket
    (``DecodeModelSpec.group_key``: all-full, or all-LoRA of one
    (alpha, rank)) stack into one StackedDecoders lane set; each distinct
    group costs one dispatch per step."""
    groups: dict = {}
    for mid, (cfg, spec) in specs.items():
        groups.setdefault((cfg, spec.group_key()), {})[mid] = spec
    return groups


class StackedDecoders:
    """All decode modules of ONE fusable group, stacked for the fused step."""

    def __init__(self, cfg, members: dict, kvpool, base_params=None):
        """``members``: {model_id: DecodeModelSpec}, all sharing ``cfg`` and
        one ``group_key``. ``base_params`` (the engine's single frozen copy)
        is required for LoRA groups — it is NOT copied: the stacked storage
        is just the A/B factors."""
        assert members, "need at least one decode module"
        self.cfg = cfg
        self.kvpool = kvpool
        self.page_size = kvpool.page_size
        self.model_ids = sorted(members)             # stable model-axis order
        self.index = {mid: m for m, mid in enumerate(self.model_ids)}
        specs = [members[mid] for mid in self.model_ids]
        self.lora = specs[0].kind == "lora"
        if self.lora:
            assert base_params is not None, "LoRA group needs the base copy"
            ad = specs[0].lora
            self.alpha, self.rank = ad.alpha, ad.rank
            # one UNBATCHED base copy (shared with the engine — no new
            # arrays) + M stacked adapter sets: the whole per-model storage
            self.stacked = {"base": base_params,
                            "ab": stack_lora_params(
                                [s.lora.params for s in specs])}
        else:
            self.stacked = stack_params([s.full for s in specs])
        self.traces = 0                              # jit retraces (tests)
        self.dispatches = 0                          # jitted-step invocations
        self._step = self._build_step()

    def param_bytes(self) -> int:
        """Bytes of decode weights THIS group stores beyond the engine's
        base copy: M × full-model bytes for full groups; the stacked A/B
        factors only for LoRA groups (the base is aliased, not copied)."""
        tree = self.stacked["ab"] if self.lora else self.stacked
        return sum(x.nbytes for x in jax.tree.leaves(tree))

    # ------------------------------------------------------------------
    def _build_step(self):
        cfg, n_full, page = self.cfg, self.kvpool.n_full, self.page_size
        wire = self.kvpool.wire_decode_cache
        if self.lora:
            alpha, rank = self.alpha, self.rank
            # vmap axes: base broadcast (None — every lane reads the ONE
            # copy), adapters split on their stacked model axis
            param_axes = {"base": None, "ab": 0}

            def lane_params(packed):
                # the Eq. 9 weight-side merge, INSIDE the jitted step: the
                # lane's effective weights exist only as an intermediate of
                # this trace, never as M materialized models in the pool
                return lora_apply(packed["base"], packed["ab"],
                                  alpha=alpha, rank=rank)
        else:
            param_axes = 0

            def lane_params(packed):
                return packed

        def fused(stacked, state, toks, pos, bts, seq_m, seq_b,
                  temps, top_ks, top_ps, seeds, greedy_only):
            # Python body runs once per trace: count retraces here.
            self.traces += 1

            def lane(packed, t, p, bt):
                cache = wire(state, bt, n_full)      # state: shared, unbatched
                logits, new_cache, _ = forward(cfg, lane_params(packed),
                                               t[:, None], cache=cache, pos=p)
                return logits, new_cache

            lg_all, caches = jax.vmap(lane, in_axes=(param_axes, 0, 0, 0))(
                stacked, toks, pos, bts)
            # Each real sequence wrote exactly ONE row, at (page, slot) named
            # by its own block table — gather those rows out of the lane-local
            # pool copies and scatter them into the shared state. Pages are
            # private per sequence (sentinel page 0 absorbs fake-row writes),
            # so indices never collide and the merge is bit-exact.
            pg_all = jnp.take_along_axis(bts, (pos // page)[..., None],
                                         axis=2)[..., 0]            # (M, Bmax)
            pg = pg_all[seq_m, seq_b]
            slot = (pos % page)[seq_m, seq_b]                       # (N,)
            new_groups = {}
            for g, st in state["groups"].items():
                ko = caches["groups"][g]["k_pages"]  # (M, n_full, P, pg, H, D)
                vo = caches["groups"][g]["v_pages"]
                rk = jnp.moveaxis(ko[seq_m, :, pg, slot], 0, 1)  # (n_full,N,H,D)
                rv = jnp.moveaxis(vo[seq_m, :, pg, slot], 0, 1)
                new_groups[g] = {"k": st["k"].at[:, pg, slot].set(rk),
                                 "v": st["v"].at[:, pg, slot].set(rv)}
            new_tail = []
            for i, st in enumerate(state["tail"]):
                ko = caches["tail"][i]["k_pages"]    # (M, P, page, H, D)
                vo = caches["tail"][i]["v_pages"]
                new_tail.append(
                    {"k": st["k"].at[pg, slot].set(ko[seq_m, pg, slot]),
                     "v": st["v"].at[pg, slot].set(vo[seq_m, pg, slot])})
            # per-request sampling, INSIDE the fused step (no extra
            # dispatch): each real sequence's logits row is gathered out of
            # its lane and sampled with a key folded from (seed, position) —
            # batch-packing-invariant; temperature=0 rows are exact argmax
            # (serving/sampling.py), keeping greedy outputs bit-identical.
            # greedy_only is STATIC: an all-greedy batch traces an
            # argmax-only step, paying none of the sampling graph.
            lg = lg_all[seq_m, seq_b]                               # (N, V)
            if greedy_only:
                nxt = jnp.argmax(lg, -1).astype(jnp.int32)
            else:
                nxt = sample_step(lg, pos[seq_m, seq_b], temps, top_ks,
                                  top_ps, seeds)
            return nxt, {"groups": new_groups, "tail": new_tail}

        # donate the pool buffers (arg 1) where donation is honoured, so the
        # fused step appends KV in place — mirrors kvcache.paged.copy_page
        donate = (1,) if jax.default_backend() == "tpu" else ()
        return jax.jit(fused, donate_argnums=donate, static_argnums=(11,))

    # ------------------------------------------------------------------
    def step(self, seqs) -> np.ndarray:
        """Advance every sequence (any mix of this group's models) one token
        in ONE jitted forward — sampled per each sequence's SamplingParams
        (greedy when temperature=0); returns next tokens aligned with
        ``seqs``. Tail pages must already cover position ``pos``."""
        M, page = len(self.model_ids), self.page_size
        counts = [0] * M
        coords = []
        for s in seqs:
            m = self.index[s.model_id]
            coords.append((m, counts[m]))
            counts[m] += 1
        bmax = max(counts)
        npages = next_pow2(max(len(s.block_table) for s in seqs))
        toks = np.zeros((M, bmax), np.int32)
        pos = np.zeros((M, bmax), np.int32)
        bts = np.zeros((M, bmax, npages), np.int32)   # pad = sentinel page 0
        for s, (m, b) in zip(seqs, coords):
            toks[m, b] = s.next_token
            pos[m, b] = s.pos
            bts[m, b, :len(s.block_table)] = s.block_table
        seq_m = jnp.asarray([m for m, _ in coords], jnp.int32)
        seq_b = jnp.asarray([b for _, b in coords], jnp.int32)
        nxt, new_state = self._step(self.stacked, self.kvpool.decode_state(),
                                    jnp.asarray(toks), jnp.asarray(pos),
                                    jnp.asarray(bts), seq_m, seq_b,
                                    *sampling_arrays(seqs))
        self.kvpool.absorb_decode_state(new_state)
        self.dispatches += 1
        return np.asarray(nxt)


class FusedDecodePlane:
    """Routes sequences to their group's StackedDecoders: one jitted dispatch
    per engine step per distinct (ModelConfig, weight-layout) group — ONE
    total when every decode module shares the engine's config and layout,
    the paper's setting.

    The plane is an immutable snapshot of the registry's model set: churn
    (hot register/unregister) REPLACES it at a step boundary
    (``LocalDisaggEngine._rebuild_decode_plane``), carrying the trace/
    dispatch counters forward so stats stay cumulative across rebuilds."""

    def __init__(self, specs, kvpool, base_params=None, *,
                 traces0: int = 0, dispatches0: int = 0):
        """specs: {model_id: (cfg, DecodeModelSpec)}."""
        self.groups = [StackedDecoders(cfg, members, kvpool, base_params)
                       for (cfg, _k), members in group_specs(specs).items()]
        self._group_of = {mid: g for g in self.groups for mid in g.model_ids}
        self._traces0 = traces0
        self._dispatches0 = dispatches0

    @property
    def model_ids(self) -> list:
        return sorted(self._group_of)

    @property
    def traces(self) -> int:
        return self._traces0 + sum(g.traces for g in self.groups)

    @property
    def dispatches(self) -> int:
        return self._dispatches0 + sum(g.dispatches for g in self.groups)

    def param_bytes(self) -> int:
        """Decode-plane weight bytes beyond the engine's single base copy
        (benchmarks/paged_decode_bench.py --adapters reports the N×full vs
        base + N·adapter ratio from exactly this)."""
        return sum(g.param_bytes() for g in self.groups)

    def step(self, seqs) -> np.ndarray:
        """One engine decode step; returns next tokens aligned with seqs."""
        nxt = np.zeros(len(seqs), np.int32)
        for g in self.groups:
            idx = [i for i, s in enumerate(seqs) if self._group_of[s.model_id] is g]
            if idx:
                nxt[idx] = g.step([seqs[i] for i in idx])
        return nxt
