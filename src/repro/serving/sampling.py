"""Per-sequence token sampling for the decode planes (pure jnp, jit-safe).

The serving API's ``SamplingParams`` are executed INSIDE the jitted decode
step (fused vmapped plane and per-model loop alike), so sampling costs no
extra dispatch. Two properties the tests pin:

  - ``temperature == 0`` is EXACTLY ``jnp.argmax(logits, -1)`` — the
    pre-redesign greedy path, bit-identical in both decode modes. The greedy
    branch is computed on the raw logits and selected with ``jnp.where``, so
    adding sampling to the step cannot perturb greedy outputs.
  - sampled streams are reproducible REGARDLESS of batch packing: the PRNG
    key for the token generated at absolute position ``p`` of a request with
    seed ``s`` is ``fold_in(PRNGKey(s), p)`` — a pure function of the
    request, never of which other sequences share the batch, which lane the
    sequence landed in, or how wide the step's padding is.

Filtering follows the usual order: top-k mask, then nucleus (top-p) mask
over the surviving distribution's sorted tail, then temperature scaling and
a categorical draw. ``top_k <= 0`` and ``top_p >= 1`` disable their filters;
the most-probable token is always kept, so the filtered distribution can
never become empty.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fold_keys(seeds, positions):
    """Per-sequence PRNG keys from (seed, position) pairs.

    ``seeds``/``positions``: (B,) int32. The fold chain depends only on the
    request's own seed and the absolute position of the token being sampled,
    so a request's random stream is invariant to batch composition.
    """

    def one(seed, pos):
        return jax.random.fold_in(jax.random.PRNGKey(seed), pos)

    return jax.vmap(one)(seeds, positions)


def sample_logits(logits, temperature, top_k, top_p, keys):
    """Sample one token per row; greedy rows (temperature <= 0) are exact
    argmax over the RAW logits.

    logits: (B, V); temperature/top_p: (B,) float32; top_k: (B,) int32;
    keys: (B, 2) uint32 (from ``fold_keys``). Returns (B,) int32.
    """
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    V = logits.shape[-1]
    neg = jnp.finfo(jnp.float32).min
    # top-k first: rank every vocab id, mask those beyond the k-th
    sort_idx = jnp.argsort(-logits, axis=-1)
    ranks = jnp.argsort(sort_idx, axis=-1)          # rank of each vocab id
    k = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    fk = jnp.where(ranks < k[:, None], logits.astype(jnp.float32), neg)
    # nucleus over the SURVIVING (top-k-renormalized) distribution: sort the
    # filtered logits and keep a sorted entry while the renormalized mass
    # STRICTLY BEFORE it is < top_p (rank 0 always survives: its exclusive
    # mass is 0; masked entries carry probability 0)
    fk_idx = jnp.argsort(-fk, axis=-1)
    fk_ranks = jnp.argsort(fk_idx, axis=-1)
    probs = jax.nn.softmax(jnp.take_along_axis(fk, fk_idx, axis=-1), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep_p = jnp.take_along_axis((cum - probs) < top_p[:, None], fk_ranks,
                                 axis=-1)
    filtered = jnp.where(keep_p, fk, neg)
    scaled = filtered / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def sample_step(logits, positions, temperature, top_k, top_p, seeds):
    """Convenience wrapper used by the decode steps: fold the per-sequence
    keys from (seed, position) and sample. All args are (B,)-aligned with
    ``logits`` rows; traceable inside jit."""
    keys = fold_keys(seeds, positions)
    return sample_logits(logits, temperature, top_k, top_p, keys)
