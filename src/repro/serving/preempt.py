"""Priority preemption: oversubscribe the pool, evict decodes, not admissions.

Without preemption, pool pressure can only HOLD new work (the scheduler's
backpressure) — a burst of long low-priority decodes starves high-priority
traffic exactly when the paper's p95 story matters. This module is the
vLLM-style escape hatch, adapted to the shared-prefill engine:

  swap-out      the victim's PRIVATE pages (CoW tail + generated KV — the
                pages nobody else can reference) move to a host-memory tier
                (kvcache/swap.py: one jitted gather per victim, timed host
                copy), the device rows become the pool's SWAPPED state
                (as-good-as-free: alloc may revoke them), and the sequence
                parks. On resume, never-revoked rows reattach with ZERO data
                movement; revoked ones scatter back into fresh rows in one
                donated whole-pool launch.
  drop &        when the victim's decoder is relay-compatible (its decode
  recompute     KV is bit-identical to base prefill — the PR 9 invariant
                that makes this legal) and the radix cache covers enough of
                its stream that re-prefilling the cold tail beats a
                host round-trip (measured-bandwidth SwapCostModel), release
                everything and re-enter the scheduler as an internal
                prefill request keyed by the full token stream.

Victim selection (``PreemptionPolicy``): lowest priority first, then fewest
private pages resident (cheapest to move), then oldest admission (LRU).
Hysteresis makes a freshly resumed victim immune for a few steps so tight
pools degrade to backpressure instead of thrashing. Either path resumes
BIT-IDENTICALLY to an un-preempted run (greedy and seeded — sampling keys
fold from (seed, absolute position), so parking shifts nothing).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from repro.kvcache.blocks import PoolExhausted
from repro.kvcache.swap import HostSwapPool
from repro.serving.costmodel import CostModel, SwapCostModel
from repro.serving.scheduler import Request


@dataclass
class PreemptConfig:
    #: admission may draw the pool down to reserve/overcommit instead of the
    #: full worst-case decode reserve — preemption is the escape hatch
    overcommit: float = 1.0
    #: steps a freshly resumed victim is immune from re-preemption
    hysteresis_steps: int = 4
    #: auto (cost model decides) | swap | recompute (forced — test hook)
    mode: str = "auto"

    def __post_init__(self):
        assert self.overcommit >= 1.0, "overcommit factor must be >= 1"
        assert self.hysteresis_steps >= 0
        assert self.mode in ("auto", "swap", "recompute"), self.mode


@dataclass
class SwapRecord:
    """One parked (swap-mode) victim: the sequence itself plus where its
    private pages sat in the block table and which of them still own their
    device rows (``resident`` shrinks when ``alloc`` revokes a row)."""
    seq: object                       # the parked DecodeSeq
    slots: list                       # [(block_table index, bid), ...]
    resident: set = field(default_factory=set)


class PreemptionPolicy:
    """Victim ordering: priority asc -> fewest private pages -> oldest rid."""

    @staticmethod
    def order(seqs):
        return sorted(seqs, key=lambda s: (s.priority,
                                           len(s.private_blocks), s.rid))


class SwapManager:
    """The engine's preemption subsystem (``engine.swap``; None unless
    ``preempt=True``). The scheduler drives it at three points per step:
    ``resume_step`` (bring parked victims back when pages allow),
    ``preempt_step`` (evict when the highest-priority pending request is
    page-blocked), and ``grow_guard`` (emergency eviction when overcommit
    left the pool unable to grow active tails)."""

    def __init__(self, engine, cfg: PreemptConfig):
        self.engine = engine
        self.cfg = cfg
        self.pool = engine.block_pool
        self.costmodel = SwapCostModel(CostModel(engine.cfg))
        self.host = HostSwapPool(observe=self.costmodel.observe)
        self.records: dict[int, SwapRecord] = {}   # rid -> parked victim
        self._bid2rid: dict[int, int] = {}
        self.resume_counts: dict[int, int] = {}    # thrash gauge (bench gate)
        self._last_resume_step: dict[int, int] = {}
        self.pool.add_swap_reclaim_callback(self._on_revoked)

    @property
    def parked(self) -> bool:
        return bool(self.records)

    def _on_revoked(self, bid: int) -> None:
        """Pool callback: ``alloc`` handed a SWAPPED page's device row to a
        new owner — the victim's copy survives only in the host tier now."""
        rid = self._bid2rid.pop(bid, None)
        if rid is not None:
            self.records[rid].resident.discard(bid)

    # ------------------------------------------------------------------
    # victim selection helpers
    # ------------------------------------------------------------------
    def _immune(self, seq) -> bool:
        last = self._last_resume_step.get(seq.rid)
        if last is None:
            return False
        steps = self.engine.scheduler.stats.steps
        return steps - last < self.cfg.hysteresis_steps

    def _stream(self, seq) -> list:
        """Token stream whose KV the victim's cache holds: prompt, then the
        handoff's first decode input, then generated bar the last token
        (whose KV was never written) — ``len == seq.pos``, the exact
        ``_relay_publish`` keying."""
        return list(seq.tokens) + [seq.first0] + [int(t) for t in seq.out[:-1]]

    def _mode_for(self, seq) -> str:
        """swap vs drop-and-recompute for this victim. Recompute is legal
        ONLY for relay-compatible decoders: resuming replays the stream
        through the BASE prefill, so the victim's decode-written KV must be
        bit-identical to base KV (the relay invariant). Among legal options
        the measured-bandwidth cost model picks the cheaper restore."""
        eng = self.engine
        dw = eng.decoders.get(seq.model_id)
        recompute_ok = (eng.relay and dw is not None and dw.relay_compatible
                        and seq.tokens)
        if self.cfg.mode == "swap" or not recompute_ok:
            return "swap"
        if self.cfg.mode == "recompute":
            return "recompute"
        if not seq.private_blocks:
            return "recompute"       # nothing to swap; dropping frees refs
        stream = self._stream(seq)
        cold = len(stream) - eng.prefix_index.match_len(stream)
        return self.costmodel.choose(
            swap_bytes=len(seq.private_blocks) * eng.kvpool.page_bytes,
            cold_tokens=cold, kv_len=len(stream))

    # ------------------------------------------------------------------
    # preemption
    # ------------------------------------------------------------------
    def _shortfall(self, sched):
        """Pages missing for the highest-priority pending request's next
        move (chunk growth or decode admission). Returns (deficit_pages,
        priority) or None when no pending request is page-blocked."""
        if not sched.prefilling:
            return None
        page = self.engine.page_size
        r = max(sched.prefilling, key=lambda q: (q.priority, -q.seq))
        if r.done < r.n:
            take = min(sched.cfg.chunk_size, r.n - r.done)
            need = -(-(r.done + take) // page) - len(r.block_table)
        else:
            cow = 1 if r.n % page else 0
            need = cow + (-(-(r.n + r.gen_tokens) // page)
                          - (-(-r.n // page)))
        if need <= 0:
            return None
        deficit = need + sched._reserve_target() - self.pool.free_count
        if deficit <= 0:
            return None
        return deficit, r.priority

    def preempt_step(self, sched) -> int:
        """Evict low-priority decodes while the highest-priority pending
        request cannot obtain pages. Only strictly lower-priority sequences
        are victims (equal-priority work degrades to backpressure — no
        peer-vs-peer thrash)."""
        info = self._shortfall(sched)
        if info is None:
            return 0
        deficit, p_hi = info
        preempted = 0
        for victim in PreemptionPolicy.order(list(sched.active)):
            if victim.priority >= p_hi:
                break                       # sorted: no victims remain
            if victim.remaining <= 0 or self._immune(victim):
                continue
            freed = self._preempt_one(victim)
            if freed is None:
                continue
            preempted += 1
            deficit -= freed
            if deficit <= 0:
                break
        return preempted

    def grow_guard(self, sched) -> int:
        """Emergency phase right before decode: overcommit may have drawn
        the pool below the active tails' entitlement, and ``alloc`` inside
        the decode step must never fail mid-flight. Preempt (lowest
        priority, preferring sequences that themselves need growth — each
        such eviction strictly improves the balance) until every tail page
        the coming step needs is coverable."""
        page = self.engine.page_size
        growing = [s for s in sched.active
                   if s.pos >= len(s.block_table) * page]
        need = len(growing)
        if need == 0 or self.pool.free_count >= need:
            return 0
        grows = {id(s) for s in growing}
        victims = sorted(sched.active,
                         key=lambda s: (s.priority, id(s) not in grows,
                                        len(s.private_blocks), s.rid))
        preempted = 0
        for s in victims:
            if self.pool.free_count >= need:
                break
            if s.remaining <= 0 or self._immune(s):
                continue
            was_growing = id(s) in grows
            if self._preempt_one(s, allow_empty=True) is None:
                continue
            preempted += 1
            if was_growing:
                need -= 1
        return preempted

    def _preempt_one(self, seq, allow_empty: bool = False):
        """Park one victim; returns pages returned to the pool's free
        capacity, or None if preempting it would reclaim nothing."""
        mode = self._mode_for(seq)
        if mode == "swap" and not seq.private_blocks and not allow_empty:
            return None
        before = self.pool.free_count
        if mode == "swap":
            self._swap_out(seq)
        else:
            self._drop_recompute(seq)
        self.engine.stats.preemptions += 1
        self.engine.metrics_registry.trace(seq.rid).event(
            "preempted", mode=mode, pages=len(seq.private_blocks))
        return self.pool.free_count - before

    def _swap_out(self, seq) -> None:
        eng = self.engine
        pset = set(seq.private_blocks)
        slots = [(i, bid) for i, bid in enumerate(seq.block_table)
                 if bid in pset]
        bids = [bid for _, bid in slots]
        if bids:
            nbytes = self.host.put(eng.kvpool, seq.rid, bids)
            eng.stats.swap_out_pages += len(bids)
            eng.stats.swap_bytes += nbytes
        self.pool.swap_out(bids)
        for bid in bids:
            self._bid2rid[bid] = seq.rid
        self.records[seq.rid] = SwapRecord(seq=seq, slots=slots,
                                           resident=set(bids))
        eng.scheduler.active.remove(seq)

    def _drop_recompute(self, seq) -> None:
        """Release the victim entirely and re-enter it as an internal
        prefill request over its full token stream: the radix cache serves
        whatever prefix survives (shared pages go to CACHED right here), the
        cold tail re-prefills through the normal chunk machinery, and
        ``_promote`` routes the handoff back through
        ``finish_recompute_resume``."""
        eng = self.engine
        sched = eng.scheduler
        stream = self._stream(seq)
        self.pool.unref(seq.shared_blocks)
        self.pool.drop(seq.private_blocks)
        sched.active.remove(seq)
        params = dataclasses.replace(seq.params, max_tokens=seq.remaining)
        sched.waiting.append(Request(
            rid=seq.rid, sid=seq.sid, model_id=seq.model_id, tokens=stream,
            gen_tokens=seq.remaining, first_token=seq.next_token,
            priority=seq.priority, seq=eng._next_seq, params=params,
            resume_seq=seq))
        eng._next_seq += 1

    # ------------------------------------------------------------------
    # resume
    # ------------------------------------------------------------------
    def _pending_outranks(self, priority: int) -> bool:
        sched = self.engine.scheduler
        return (any(r.priority > priority for r in sched.waiting)
                or any(r.priority > priority for r in sched.prefilling))

    def resume_step(self, sched) -> int:
        """Un-park swap-mode victims, highest priority first, when (a) no
        strictly-higher-priority request is still pending and (b) the pool
        can host the victim's pages PLUS its remaining tail growth above
        the admission reserve — resuming must never re-create the pressure
        that parked it."""
        if not self.records:
            return 0
        page = self.engine.page_size
        resumed = 0
        order = sorted(self.records,
                       key=lambda rid: (-self.records[rid].seq.priority, rid))
        for rid in order:
            rec = self.records[rid]
            seq = rec.seq
            if self._pending_outranks(seq.priority):
                continue
            missing = [(j, ti) for j, (ti, bid) in enumerate(rec.slots)
                       if bid not in rec.resident]
            growth = max(0, -(-(seq.pos + seq.remaining) // page)
                         - len(seq.block_table))
            if (self.pool.free_count - len(rec.resident) - len(missing)
                    - growth < sched._reserve_target()):
                continue
            if self._resume_one(rid, rec, missing):
                resumed += 1
        return resumed

    def _resume_one(self, rid: int, rec: SwapRecord, missing) -> bool:
        eng = self.engine
        seq = rec.seq
        # reclaim the still-resident rows FIRST (zero data movement, cannot
        # fail) so the allocation below can never revoke this record's own
        # pages out from under the resume
        still = [bid for _, bid in rec.slots if bid in rec.resident]
        self.pool.reclaim_swapped(still)
        fresh = []
        try:
            if missing:
                fresh = self.pool.alloc(len(missing))
        except PoolExhausted:
            # roll back to parked: the reclaimed rows return to the tier
            self.pool.swap_out(still)
            return False
        if missing:
            nbytes = self.host.restore(
                eng.kvpool, rid, [j for j, _ in missing], fresh)
            remap = {}
            for (j, ti), nb in zip(missing, fresh):
                seq.block_table[ti] = nb
                remap[rec.slots[j][1]] = nb
            seq.private_blocks = [remap.get(b, b)
                                  for b in seq.private_blocks]
            eng.stats.swap_in_pages += len(missing)
            eng.stats.swap_bytes += nbytes
        for _, bid in rec.slots:
            self._bid2rid.pop(bid, None)
        self.host.pop(rid)
        del self.records[rid]
        eng.scheduler.active.append(seq)
        self._mark_resumed(rid, "swap", len(missing))
        return True

    def finish_recompute_resume(self, req, seq) -> None:
        """``_promote`` hook for a drop-and-recompute victim's internal
        request: the handoff built a fresh DecodeSeq over the re-prefilled
        stream — graft the victim's identity back on so the continuation is
        indistinguishable from never having been preempted (out/params/
        prompt restored; pos, next_token, remaining already exact)."""
        victim = req.resume_seq
        seq.out = victim.out
        seq.tokens = victim.tokens
        seq.first0 = victim.first0
        seq.params = victim.params
        seq.priority = victim.priority
        self._mark_resumed(seq.rid, "recompute", 0)

    def _mark_resumed(self, rid: int, mode: str, pages: int) -> None:
        self.resume_counts[rid] = self.resume_counts.get(rid, 0) + 1
        self._last_resume_step[rid] = self.engine.scheduler.stats.steps
        self.engine.metrics_registry.trace(rid).event(
            "resumed", mode=mode, pages=pages)

    # ------------------------------------------------------------------
    # abort while swapped
    # ------------------------------------------------------------------
    def abort(self, rid: int) -> None:
        """Drop a parked victim: cached-prefix refs released, still-resident
        swapped rows freed (revoked rows already belong to new owners), host
        copy discarded — the pool returns exactly to baseline."""
        rec = self.records.pop(rid)
        self.pool.unref(rec.seq.shared_blocks)
        self.pool.discard_swapped(
            [bid for _, bid in rec.slots if bid in rec.resident])
        for _, bid in rec.slots:
            self._bid2rid.pop(bid, None)
        self.host.pop(rid)
