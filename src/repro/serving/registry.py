"""Model-lifecycle registry: hot (un)register decode models while serving.

The paper's premise is a POOL of task-specific decode modules sharing one
frozen prefill module — and production multi-LLM serving (vLLM LoRA
hot-swap, S-LoRA's adapter pools) treats that pool as dynamic: adapters
arrive and retire while traffic flows. ``engine.models`` is that surface:

    engine.models.register("summarizer", DecodeModelSpec(
        lora=LoRAAdapter(params=lora_init(key, base, rank=8))))
    engine.models.register("planner", DecodeModelSpec(full=planner_params))
    ...
    engine.models.unregister("planner", drain=True)   # or drain=False

Lifecycle semantics:
  - ``register`` takes effect for NEW requests immediately; the fused decode
    plane is rebuilt at the next STEP BOUNDARY (``sync``, called by the
    scheduler at the top of every step), never mid-step. Live sequences are
    addressed by model id, and the rebuilt plane re-derives every sequence's
    model-lane index per step, so a churn event remaps lanes without
    touching any sequence's pages or sampling keys — surviving requests'
    outputs are bit-identical across the churn (tests/test_registry.py).
  - ``unregister(drain=True)`` stops NEW work instantly (first-class
    ``UnknownModelError``) but lets in-flight requests (waiting, prefilling,
    decoding) finish; the model's lane is dropped from the plane once the
    last one retires.
  - ``unregister(drain=False)`` aborts the model's in-flight requests
    through the engine's existing abort path (every page refcount returns to
    baseline) and removes the model at the next step boundary.

Weight layout per spec kind (serving/decode.py):
  - ``full=params``: the model's full pytree joins the stacked model axis.
  - ``lora=LoRAAdapter(...)``: only the (tiny) stacked A/B factors are
    stored; the frozen base weights are the ENGINE's single copy, and the
    merge ``W + scale * A[m] @ B[m]`` happens inside the jitted vmapped
    decode step — one base copy + N adapter sets instead of N full models
    (Eq. 9 on the weight side).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.core.lora import lora_apply
from repro.serving.api import UnknownModelError


@dataclass(frozen=True)
class LoRAAdapter:
    """An adapter-factored decode module: ``W_eff = W + (alpha/rank)·A@B``
    over the engine's frozen base weights. ``params`` is a ``lora_init``-
    style pytree (``LoRAPair`` at targeted weights, None elsewhere)."""
    params: Any
    alpha: float = 16.0
    rank: int = 8

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


class DecodeModelSpec:
    """How a registered decode model stores its weights: exactly one of

    - ``full=params``   — a complete param pytree (the paper's full
      fine-tunes); the fused plane stacks it on the model axis.
    - ``lora=LoRAAdapter(...)`` — base + low-rank factors; the fused plane
      stores only the stacked factors and merges inside the step.
    """

    def __init__(self, full: Any = None, lora: LoRAAdapter | None = None):
        if (full is None) == (lora is None):
            raise ValueError(
                "DecodeModelSpec takes exactly one of full=params or "
                "lora=LoRAAdapter(...)")
        if lora is not None and not isinstance(lora, LoRAAdapter):
            raise TypeError(f"lora= expects a LoRAAdapter, got {type(lora)}")
        self.full = full
        self.lora = lora

    @property
    def kind(self) -> str:
        return "full" if self.full is not None else "lora"

    def group_key(self):
        """Fusability bucket within one ModelConfig: full models stack with
        full models; adapters stack only with adapters of the same
        (alpha, rank) — their stacked A/B shapes and merge scale agree."""
        if self.full is not None:
            return ("full",)
        return ("lora", self.lora.alpha, self.lora.rank)

    def materialize(self, base_params):
        """Full effective params (the legacy per-model decode layout)."""
        if self.full is not None:
            return self.full
        return lora_apply(base_params, self.lora.params,
                          alpha=self.lora.alpha, rank=self.lora.rank)

    def __repr__(self):
        if self.full is not None:
            return "DecodeModelSpec(full=<params>)"
        return (f"DecodeModelSpec(lora=LoRAAdapter(rank={self.lora.rank}, "
                f"alpha={self.lora.alpha}))")


def as_spec(obj) -> DecodeModelSpec:
    """Coerce to a spec: raw param pytrees register as full models (the
    construction-time ``decoders: dict`` shim feeds through here)."""
    if isinstance(obj, DecodeModelSpec):
        return obj
    if isinstance(obj, LoRAAdapter):
        return DecodeModelSpec(lora=obj)
    return DecodeModelSpec(full=obj)


class ModelRegistry:
    """The engine's decode-model set, mutable while serving.

    Mutations are split into an immediate half (bookkeeping: new requests
    validate against the registry the moment ``register``/``unregister``
    returns) and a deferred half (the fused plane's stacked layout), applied
    by ``sync()`` at step boundaries only — a stream callback may call
    ``register``/``unregister`` from INSIDE a decode step, and rebuilding
    the plane mid-step would cross-wire that step's lane routing."""

    def __init__(self, engine):
        self.engine = engine
        self._specs: dict[str, DecodeModelSpec] = {}
        self._draining: set[str] = set()
        self._dirty = False        # plane layout stale (rebuild at sync)
        self.version = 0           # bumped on every accepted mutation

    # -- queries -------------------------------------------------------
    def list(self) -> list[str]:
        """Registered model ids (draining models included until retired)."""
        return sorted(self._specs)

    def get(self, model_id: str) -> DecodeModelSpec:
        self._check_known(model_id)
        return self._specs[model_id]

    def __contains__(self, model_id) -> bool:
        return model_id in self._specs

    def __len__(self) -> int:
        return len(self._specs)

    def __iter__(self):
        return iter(self.list())

    @property
    def draining(self) -> frozenset:
        return frozenset(self._draining)

    def _check_known(self, model_id: str) -> None:
        if model_id not in self._specs:
            raise UnknownModelError(
                f"model {model_id!r} is not registered "
                f"(registered: {self.list() or 'none'}); add it with "
                f"engine.models.register(model_id, DecodeModelSpec(...))")

    def check_serving(self, model_id: str) -> None:
        """Validate a model id for NEW work (generate/submit)."""
        self._check_known(model_id)
        if model_id in self._draining:
            raise UnknownModelError(
                f"model {model_id!r} is draining (unregister pending): it "
                f"accepts no new requests; in-flight ones will finish")

    # -- mutations -----------------------------------------------------
    def register(self, model_id: str, spec) -> None:
        """Add a decode model while serving. ``spec`` is a DecodeModelSpec,
        a LoRAAdapter, or a raw param pytree (registered as full). New
        requests may target it immediately; its fused-plane lane appears at
        the next step boundary."""
        if model_id in self._specs:
            state = "draining" if model_id in self._draining else "registered"
            raise ValueError(
                f"model {model_id!r} is already {state}; unregister it "
                f"(and let it drain) before re-registering")
        spec = as_spec(spec)
        self._specs[model_id] = spec
        self.engine._attach_decoder(model_id, spec)
        self._dirty = True
        self.version += 1
        self.engine.stats.model_churn_events += 1

    def unregister(self, model_id: str, *, drain: bool = True) -> bool:
        """Retire a decode model. With ``drain=True`` (default) in-flight
        requests finish first; with ``drain=False`` they are aborted through
        the engine's abort path (pages back to baseline). Returns True if
        the model is fully gone on return, False if it is draining."""
        self._check_known(model_id)
        if model_id in self._draining:
            raise ValueError(f"model {model_id!r} is already draining")
        self.version += 1
        self.engine.stats.model_churn_events += 1
        if not drain:
            for rid in self.engine._inflight_rids(model_id):
                self.engine.abort(rid)
        if self.engine._has_inflight(model_id):
            # drain=True with live work (drain=False cannot reach here: a
            # non-abortable remaining<=0 sequence is reaped at the next step,
            # after which sync() finalizes)
            self._draining.add(model_id)
            return False
        self._finalize(model_id)
        return True

    # -- step-boundary application --------------------------------------
    def sync(self) -> None:
        """Apply deferred mutations; called by the scheduler at the top of
        every step (and once at engine construction). No-op when clean."""
        for model_id in sorted(self._draining):
            if not self.engine._has_inflight(model_id):
                self._draining.discard(model_id)
                self._finalize(model_id)
        if self._dirty:
            self._dirty = False
            self.engine._rebuild_decode_plane()

    def _finalize(self, model_id: str) -> None:
        del self._specs[model_id]
        self.engine._detach_decoder(model_id)
        self._dirty = True

    def __repr__(self):
        drain = f", draining={sorted(self._draining)}" if self._draining else ""
        return f"ModelRegistry({self.list()}{drain})"
