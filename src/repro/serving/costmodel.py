"""TPU v5e cost model for prefill/decode step times.

Analytic three-term roofline (compute / HBM / interconnect) per step, with an
optional calibration path that scales the analytic terms to the dry-run's
compiled cost_analysis (benchmarks/roofline.py writes the calibration JSON).
The event-driven serving simulator prices every operation through this model,
which is how the paper's A100 numbers are re-grounded on TPU (DESIGN.md §3).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.kvcache.manager import kv_bytes_per_token, state_bytes_per_seq

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
MXU_EFF = 0.55               # sustained fraction of peak for big matmuls
BW_EFF = 0.80


@dataclass
class StepCost:
    seconds: float
    compute_s: float
    memory_s: float
    flops: float
    bytes: float


class CostModel:
    def __init__(self, cfg: ModelConfig, *, chips: int = 1,
                 dtype_bytes: int = 2, calibration: dict | None = None):
        self.cfg = cfg
        self.chips = chips
        self.db = dtype_bytes
        self.n_params = cfg.param_count()
        self.n_active = cfg.active_param_count()
        self.kv_per_tok = kv_bytes_per_token(cfg, dtype_bytes)
        self.state_per_seq = state_bytes_per_seq(cfg)
        # calibration: multiplicative fudge from compiled dry-run artifacts
        self.flops_scale = 1.0
        self.bytes_scale = 1.0
        if calibration:
            self.flops_scale = calibration.get("flops_scale", 1.0)
            self.bytes_scale = calibration.get("bytes_scale", 1.0)

    @classmethod
    def from_calibration_file(cls, cfg, path, **kw):
        calib = None
        if os.path.exists(path):
            with open(path) as f:
                calib = json.load(f).get(cfg.name)
        return cls(cfg, calibration=calib, **kw)

    # ------------------------------------------------------------------
    def _attn_flops(self, n_new: int, kv_len: int, batch: int) -> float:
        """Attention score+value FLOPs (grows with context)."""
        cfg = self.cfg
        total = 0.0
        for kind in cfg.layer_kinds():
            if kind == "attn":
                eff_kv = kv_len
            elif kind == "local_attn":
                eff_kv = min(kv_len, cfg.sliding_window or kv_len)
            else:
                continue
            total += 4.0 * batch * n_new * eff_kv * cfg.n_heads * cfg.head_dim
        return total

    def prefill(self, n_new: int, kv_len: int, batch: int = 1) -> StepCost:
        """Process ``n_new`` prompt tokens against ``kv_len`` existing cache."""
        flops = (2.0 * self.n_active * n_new * batch
                 + self._attn_flops(n_new, kv_len + n_new, batch))
        flops *= self.flops_scale
        bytes_ = (self.n_params * self.db          # weights stream once
                  + batch * (kv_len + n_new) * self.kv_per_tok) * self.bytes_scale
        c = flops / (self.chips * PEAK_FLOPS * MXU_EFF)
        m = bytes_ / (self.chips * HBM_BW * BW_EFF)
        return StepCost(max(c, m), c, m, flops, bytes_)

    def decode_step(self, batch: int, avg_kv_len: float) -> StepCost:
        """One token for every sequence in the decode batch."""
        flops = (2.0 * self.n_active * batch
                 + self._attn_flops(1, int(avg_kv_len), batch)) * self.flops_scale
        bytes_ = (self.n_params * self.db
                  + batch * (avg_kv_len * self.kv_per_tok + self.state_per_seq)
                  ) * self.bytes_scale
        c = flops / (self.chips * PEAK_FLOPS * MXU_EFF)
        m = bytes_ / (self.chips * HBM_BW * BW_EFF)
        return StepCost(max(c, m), c, m, flops, bytes_)


class SwapCostModel:
    """Swap-vs-recompute pricing for preemption (serving/preempt.py).

    Swapping a victim costs two host transfers (gather out now, scatter back
    at resume) at MEASURED device<->host bandwidth — every transfer the
    HostSwapPool performs feeds ``observe``, so the estimate converges on
    the deployment's real link, not a constant. Recomputing costs one
    prefill of the tokens the radix cache cannot serve (PPD's 'not all
    prefills are equal': a victim whose stream is fully relay/prefix-covered
    re-prefills almost for free, and dropping beats transferring).
    """

    #: conservative host-link prior before any measurement (bytes/s)
    DEFAULT_HOST_BW = 10e9

    def __init__(self, cost: CostModel):
        self.cost = cost
        self.host_bw = self.DEFAULT_HOST_BW
        self.samples = 0

    def observe(self, nbytes: int, seconds: float) -> None:
        """EWMA a measured host transfer into the bandwidth estimate."""
        if seconds <= 0 or nbytes <= 0:
            return
        bw = nbytes / seconds
        self.host_bw = bw if self.samples == 0 else (
            0.8 * self.host_bw + 0.2 * bw)
        self.samples += 1

    def transfer_s(self, nbytes: int) -> float:
        return nbytes / max(self.host_bw, 1.0)

    def recompute_s(self, cold_tokens: int, kv_len: int) -> float:
        """Re-prefill cost for the tokens the prefix/relay cache misses."""
        if cold_tokens <= 0:
            return 0.0
        return self.cost.prefill(cold_tokens, kv_len - cold_tokens).seconds

    def choose(self, *, swap_bytes: int, cold_tokens: int,
               kv_len: int) -> str:
        """'recompute' when re-prefilling the cache-cold tail beats moving
        the KV host-side and back; 'swap' otherwise."""
        round_trip = self.transfer_s(2 * swap_bytes)
        return ("recompute"
                if self.recompute_s(cold_tokens, kv_len) < round_trip
                else "swap")
