"""Observability control plane: typed metrics + per-request lifecycle traces.

The paper's headline claims are TAIL-LATENCY claims (4.5x lower p95, 3.9x
higher throughput), so the engine needs a first-class latency/occupancy
surface — not a counter grab-bag. This module is that surface:

  - ``Counter`` / ``Gauge`` / ``Histogram``: typed primitives. Histograms use
    FIXED log-spaced buckets (geometric bounds), so ``observe`` is one bisect
    + one int increment — no per-sample storage, O(1) memory regardless of
    traffic — and export p50/p95/p99 by interpolating inside the owning
    bucket (relative error bounded by the bucket growth factor; see
    ``Histogram.percentile``). Gauges may be value-set or COLLECTOR-backed
    (``fn=``): the callable is sampled at snapshot/render time, which is how
    pool occupancy, radix-tree size, and queue depths publish without any
    hot-path writes.
  - ``RequestTrace``: one request's lifecycle as timestamped span events —
    queued -> routed -> chunk_prefilled (per chunk) -> handoff ->
    first_token -> token (per-token ITL) -> finished | aborted — recorded at
    the SAME push points ``RequestOutput`` already timestamps, so trace
    timings are exactly what a streaming client observes. Traces are kept in
    a bounded ring (``trace_capacity``); abort at ANY stage closes the trace
    with an ``aborted`` terminal event.
  - ``MetricsRegistry``: the one sink the engine, router, scheduler, pool,
    and prefix index publish into. Exported two ways: ``snapshot()`` as
    structured dicts (what ``engine.metrics()`` returns) and
    ``render_prometheus()`` as Prometheus text exposition (the
    production-stack router/KEDA scrape pattern). ``lint_prometheus``
    validates the exposition format (CI gate: no duplicate/unnamed series).

Disabled mode (``MetricsRegistry(enabled=False)``): histograms, gauges, and
traces degrade to shared no-op singletons whose methods take fixed-arity
arguments (no ``*args`` tuple build), so the decode hot loop pays one
attribute lookup + one no-op call and ZERO allocations per would-be sample
(asserted in tests/test_metrics.py). Counters stay REAL even when disabled:
they back the pre-existing ``engine.stats()`` counter surface, which must
keep working with observability off.
"""
from __future__ import annotations

import math
import time
from bisect import bisect_left
from collections import OrderedDict

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RequestTrace",
    "NullHistogram", "NullGauge", "NullTrace", "lint_prometheus",
    "SPAN_QUEUED", "SPAN_ROUTED", "SPAN_CHUNK", "SPAN_HANDOFF",
    "SPAN_FIRST_TOKEN", "SPAN_TOKEN", "SPAN_FINISHED", "SPAN_ABORTED",
]

# trace span-event names (one vocabulary, engine-wide)
SPAN_QUEUED = "queued"
SPAN_ROUTED = "routed"
SPAN_CHUNK = "chunk_prefilled"
SPAN_HANDOFF = "handoff"
SPAN_FIRST_TOKEN = "first_token"
SPAN_TOKEN = "token"
SPAN_FINISHED = "finished"
SPAN_ABORTED = "aborted"

#: terminal events — a trace is closed once it carries one of these
_TERMINAL = (SPAN_FINISHED, SPAN_ABORTED)


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats repr-exact."""
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class Counter:
    """Monotonic counter. ``value`` is readable/writable directly so the
    legacy ``EngineStats`` attribute surface can be re-implemented as a thin
    view over registry counters (``stats.handoffs += 1`` keeps working)."""

    __slots__ = ("name", "help", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = "", labels: tuple = ()):
        self.name = name
        self.help = help
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Point-in-time value: ``set()`` it, or back it with a collector
    callable (``fn=``) sampled at snapshot/render time."""

    __slots__ = ("name", "help", "labels", "value", "fn")
    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels: tuple = (),
                 fn=None):
        self.name = name
        self.help = help
        self.labels = labels
        self.value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self):
        if self.fn is not None:
            return float(self.fn())
        return self.value


class Histogram:
    """Fixed log-bucket histogram with interpolated percentile export.

    Bucket upper bounds are geometric: ``lo * growth**i`` up to ``hi``, plus
    a +Inf overflow bucket; values at or below ``lo`` land in bucket 0.
    ``observe`` is a bisect into the (precomputed) bounds plus one integer
    increment — no per-sample storage. ``percentile`` walks the cumulative
    counts to the owning bucket and interpolates linearly inside it, clamped
    to the observed [min, max], so the estimate's relative error is bounded
    by the bucket growth factor (default 1.25 => <= 25% worst case,
    typically far less — gated against numpy quantiles in
    tests/test_metrics.py)."""

    __slots__ = ("name", "help", "labels", "bounds", "counts", "count",
                 "sum", "_min", "_max")
    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: tuple = (), *,
                 lo: float = 1e-6, hi: float = 4e3, growth: float = 1.25):
        assert lo > 0 and hi > lo and growth > 1.0
        self.name = name
        self.help = help
        self.labels = labels
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        self.bounds = [lo * growth ** i for i in range(n + 1)]
        self.counts = [0] * (len(self.bounds) + 1)   # [+Inf overflow at -1]
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    def percentile(self, q: float) -> float:
        """q in [0, 100]. NaN when empty."""
        if self.count == 0:
            return float("nan")
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo_edge = self.bounds[i - 1] if i > 0 else 0.0
                hi_edge = (self.bounds[i] if i < len(self.bounds)
                           else self._max)
                frac = (target - cum) / c
                est = lo_edge + (hi_edge - lo_edge) * max(frac, 0.0)
                return min(max(est, self._min), self._max)
            cum += c
        return self._max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self._min if self.count else float("nan"),
            "max": self._max if self.count else float("nan"),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }

    def cumulative_buckets(self):
        """(upper_bound, cumulative_count) pairs, +Inf last — the Prometheus
        histogram exposition layout. Zero-count buckets are skipped (bounded
        output) except +Inf, which is always present."""
        out = []
        cum = 0
        for i, c in enumerate(self.counts[:-1]):
            cum += c
            if c:
                out.append((self.bounds[i], cum))
        out.append((math.inf, cum + self.counts[-1]))
        return out


# ----------------------------------------------------------------------
# disabled-mode singletons: fixed-arity no-op methods (NO *args tuple
# build), shared instances (no per-call or per-metric allocation)


class NullHistogram:
    __slots__ = ()
    kind = "histogram"

    def observe(self, v):
        pass

    def percentile(self, q):
        return float("nan")

    def snapshot(self):
        return {"count": 0, "sum": 0.0}


class NullGauge:
    __slots__ = ()
    kind = "gauge"

    def set(self, v):
        pass

    def snapshot(self):
        return 0.0


class NullTrace:
    __slots__ = ()

    def event(self, name, t=None, **attrs):
        pass

    def close(self, reason, t=None):
        pass


_NULL_HISTOGRAM = NullHistogram()
_NULL_GAUGE = NullGauge()
_NULL_TRACE = NullTrace()


# ----------------------------------------------------------------------


class RequestTrace:
    """One request's lifecycle as timestamped span events.

    ``events`` is a list of ``(name, t, attrs)`` tuples in record order;
    ``t`` is ``time.perf_counter()`` at record time — the SAME clock (and,
    for first_token/token, the same timestamps) ``RequestOutput`` exposes.
    ``close(reason)`` appends the terminal event exactly once (idempotent:
    a finished trace ignores later events, so an abort racing a finish
    cannot double-terminate)."""

    __slots__ = ("rid", "model_id", "events", "done")

    def __init__(self, rid: int, model_id=None, t: float | None = None):
        self.rid = rid
        self.model_id = model_id
        self.events: list = []
        self.done = False
        self.event(SPAN_QUEUED, t=t)

    def event(self, name: str, t: float | None = None, **attrs) -> None:
        if self.done:
            return
        self.events.append((name, time.perf_counter() if t is None else t,
                            attrs or None))
        if name in _TERMINAL:
            self.done = True

    def close(self, reason: str, t: float | None = None) -> None:
        """Terminal event: ``finished`` (reason attr) or ``aborted``."""
        if reason == "abort":
            self.event(SPAN_ABORTED, t=t)
        else:
            self.event(SPAN_FINISHED, t=t, reason=reason)

    # -- derived spans --------------------------------------------------
    def _t(self, name: str) -> float | None:
        for n, t, _ in self.events:
            if n == name:
                return t
        return None

    def span(self, start: str, end: str) -> float | None:
        """Seconds between the first occurrence of two events."""
        a, b = self._t(start), self._t(end)
        return (b - a) if a is not None and b is not None else None

    @property
    def ttft_s(self) -> float | None:
        return self.span(SPAN_QUEUED, SPAN_FIRST_TOKEN)

    def as_dict(self) -> dict:
        return {
            "rid": self.rid,
            "model_id": self.model_id,
            "done": self.done,
            "events": [
                {"name": n, "t": t, **(attrs or {})}
                for n, t, attrs in self.events
            ],
        }

    def __repr__(self):
        tail = self.events[-1][0] if self.events else "?"
        return (f"RequestTrace(rid={self.rid}, events={len(self.events)}, "
                f"last={tail!r})")


class MetricsRegistry:
    """One sink for every publisher; get-or-create metric factories keyed on
    (name, labels). ``enabled=False`` degrades histograms/gauges/traces to
    shared no-op singletons (counters stay real — they back the legacy
    ``engine.stats()`` surface, see module docstring)."""

    def __init__(self, enabled: bool = True, *, trace_capacity: int = 256):
        self.enabled = enabled
        self._metrics: "OrderedDict[tuple, object]" = OrderedDict()
        self._traces: "OrderedDict[int, RequestTrace]" = OrderedDict()
        self.trace_capacity = trace_capacity

    # -- factories -------------------------------------------------------
    def _get(self, cls, name, help, labels, **kw):
        key = (name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, help, labels, **kw)
            self._metrics[key] = m
        elif m.kind != cls.kind:
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    @staticmethod
    def _labels(labels: dict | None) -> tuple:
        return tuple(sorted((str(k), str(v))
                            for k, v in (labels or {}).items()))

    def counter(self, name: str, help: str = "",
                labels: dict | None = None) -> Counter:
        # counters are REAL even when disabled (stats() runs on them)
        return self._get(Counter, name, help, self._labels(labels))

    def gauge(self, name: str, help: str = "", labels: dict | None = None,
              fn=None):
        if not self.enabled:
            return _NULL_GAUGE
        g = self._get(Gauge, name, help, self._labels(labels))
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "",
                  labels: dict | None = None, *, lo: float = 1e-6,
                  hi: float = 4e3, growth: float = 1.25):
        if not self.enabled:
            return _NULL_HISTOGRAM
        return self._get(Histogram, name, help, self._labels(labels),
                         lo=lo, hi=hi, growth=growth)

    # -- traces ----------------------------------------------------------
    def start_trace(self, rid: int, model_id=None, t: float | None = None):
        if not self.enabled:
            return _NULL_TRACE
        tr = RequestTrace(rid, model_id, t=t)
        self._traces[rid] = tr
        while len(self._traces) > self.trace_capacity:
            self._traces.popitem(last=False)
        return tr

    def trace(self, rid: int):
        """The live/retained trace for ``rid`` (no-op singleton when absent
        or disabled, so call sites never branch)."""
        return self._traces.get(rid, _NULL_TRACE)

    def traces(self) -> list:
        return list(self._traces.values())

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Structured dict view: {counters, gauges, histograms}, labeled
        series keyed ``name{k="v",...}``."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, labels), m in self._metrics.items():
            key = name + _label_str(labels)
            out[m.kind + "s"][key] = m.snapshot()
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4): HELP/TYPE once per
        metric name, then every labeled series; histograms render cumulative
        ``_bucket{le=...}`` + ``_sum`` + ``_count``."""
        by_name: "OrderedDict[str, list]" = OrderedDict()
        for (name, _labels), m in self._metrics.items():
            by_name.setdefault(name, []).append(m)
        lines = []
        for name, ms in by_name.items():
            help_text = next((m.help for m in ms if m.help), "")
            lines.append(f"# HELP {name} {help_text or name}")
            lines.append(f"# TYPE {name} {ms[0].kind}")
            for m in ms:
                ls = _label_str(m.labels)
                if m.kind == "histogram":
                    for ub, cum in m.cumulative_buckets():
                        le = "+Inf" if math.isinf(ub) else repr(ub)
                        sep = "," if m.labels else ""
                        base = ls[:-1] + sep if m.labels else "{"
                        lines.append(
                            f'{name}_bucket{base}le="{le}"}} {cum}')
                    lines.append(f"{name}_sum{ls} {_fmt(m.sum)}")
                    lines.append(f"{name}_count{ls} {m.count}")
                else:
                    lines.append(f"{name}{ls} {_fmt(m.snapshot())}")
        return "\n".join(lines) + "\n"


# ----------------------------------------------------------------------


def lint_prometheus(text: str) -> list[str]:
    """Validate Prometheus text exposition; returns a list of problems
    (empty = clean). Checks the failure modes a scrape actually rejects or
    silently corrupts on: unnamed/garbage sample lines, duplicate series
    (same name + label set twice), samples with no TYPE/HELP header,
    histograms missing the +Inf bucket or with non-monotonic cumulative
    bucket counts, and non-numeric sample values. CI runs the engine's
    render output through this (metrics-smoke job)."""
    problems: list[str] = []
    typed: dict[str, str] = {}
    helped: set[str] = set()
    seen_series: set[str] = set()
    hist_buckets: dict[str, list] = {}

    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3:
                problems.append(f"line {ln}: malformed HELP")
            else:
                helped.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                problems.append(f"line {ln}: malformed TYPE")
            else:
                if parts[2] in typed:
                    problems.append(f"line {ln}: duplicate TYPE for {parts[2]}")
                typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        # sample line: name{labels} value
        head, _, val = line.rpartition(" ")
        if not head:
            problems.append(f"line {ln}: unnamed sample {line!r}")
            continue
        try:
            float(val)
        except ValueError:
            problems.append(f"line {ln}: non-numeric value {val!r}")
        series = head.strip()
        name = series.split("{", 1)[0]
        if not name or not name[0].isalpha() and name[0] != "_":
            problems.append(f"line {ln}: unnamed/invalid series {series!r}")
            continue
        if series in seen_series:
            problems.append(f"line {ln}: duplicate series {series!r}")
        seen_series.add(series)
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in typed:
                base = name[: -len(suffix)]
        if base not in typed:
            problems.append(f"line {ln}: sample {name!r} has no TYPE header")
        if base not in helped:
            problems.append(f"line {ln}: sample {name!r} has no HELP header")
        if name.endswith("_bucket") and typed.get(base) == "histogram":
            lab = series.split("{", 1)[1] if "{" in series else ""
            le = None
            for part in lab.rstrip("}").split(","):
                if part.startswith('le="'):
                    le = part[4:-1]
            key = base + "|" + ",".join(
                p for p in lab.rstrip("}").split(",")
                if not p.startswith('le="'))
            ub = math.inf if le == "+Inf" else float(le)
            hist_buckets.setdefault(key, []).append((ub, float(val), ln))

    for key, buckets in hist_buckets.items():
        buckets.sort(key=lambda b: b[0])
        if not buckets or not math.isinf(buckets[-1][0]):
            problems.append(f"histogram {key.split('|')[0]}: no +Inf bucket")
        last = -1.0
        for ub, cum, ln in buckets:
            if cum < last:
                problems.append(
                    f"line {ln}: histogram {key.split('|')[0]} cumulative "
                    f"bucket counts decrease")
            last = cum
    return problems
