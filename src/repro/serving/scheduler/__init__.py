"""Chunked-prefill scheduler subsystem: token-budget step batching.

See ``scheduler.py`` for the step loop and ``queue.py`` for admission
ordering policies. The engine (``repro.serving.engine``) delegates its run
loop here; the paged data plane it schedules over lives in
``repro.kvcache`` and the per-chunk attention kernel in
``repro.kernels.flash_prefill_paged``.
"""
from repro.serving.scheduler.queue import POLICIES, order_requests
from repro.serving.scheduler.scheduler import (ChunkedScheduler, Request,
                                               SchedStats, SchedulerConfig)

__all__ = ["ChunkedScheduler", "Request", "SchedStats", "SchedulerConfig",
           "POLICIES", "order_requests"]
