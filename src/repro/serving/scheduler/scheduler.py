"""Continuous token-budget step scheduler: the engine's run loop.

Every engine step packs, under one per-step token budget:
  - one decode token for EVERY active sequence (decode is never starved —
    each active sequence reserves one budget token), and
  - as many prefill CHUNKS as fit the remaining budget, split off waiting
    prompts at ``chunk_size`` granularity in policy order (fcfs/priority).

Long prompts therefore stop head-of-line-blocking the decode plane: a 10k
prompt becomes many budget-sized slices interleaved with everyone else's
decode steps, instead of one monolithic forward that stalls every sequence
behind it (the paper's prefill-decode interference, and the top ROADMAP item).

Data plane per chunk: pages are allocated CHUNK-GRANULARLY (``CacheManager
.extend`` — only the pages this chunk spills into, so a request's pool
footprint grows with progress, not with prompt length), and the chunk runs
through ``base_prefill_chunk``: one jitted forward in which each layer
scatters its fresh K/V into the pages and attends prefix+self straight from
the pool via ``flash_prefill_paged`` — no dense gather of the prefix, ever.
Equal-length chunks from different requests batch into ONE base-model
forward.

Backpressure is wired to the existing pool machinery: ``PoolExhausted`` on a
chunk's page growth (or on the handoff's CoW clone) holds that request —
pages it already computed stay put — and retries after decode steps free
pages; a step that can make no progress at all raises ``PoolExhausted``
rather than spinning.

The same object also runs the legacy eager mode (chunking off): ``submit``
prefills whole prompts synchronously and the scheduler's step is decode-only
— semantically today's engine, which is what the chunked path is tested
bit-identical against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.prefillshare import base_prefill_chunk
from repro.kvcache.blocks import PoolExhausted
from repro.serving.decode import next_pow2
from repro.serving.scheduler.queue import POLICIES, order_requests


@dataclass
class SchedulerConfig:
    token_budget: int = 256      # per-step cap: decode tokens + chunk tokens
    chunk_size: int = 64         # max prefill tokens per request per step
    policy: str = "fcfs"         # fcfs | priority (queue.py)
    cached_first: bool = True    # chunk-budget order: cached-history prefills
                                 # before cold prompts within a priority class
                                 # (PPD; see queue.py — schedule-only, token
                                 # streams stay bit-identical)

    def __post_init__(self):
        assert self.token_budget > 0 and self.chunk_size > 0
        assert self.policy in POLICIES, self.policy


@dataclass
class SchedStats:
    steps: int = 0
    chunks: int = 0
    chunk_tokens: int = 0
    stalls: int = 0              # chunk/handoff attempts deferred on pool pressure
    max_prefill_batch: int = 0   # widest batched chunk forward


@dataclass(eq=False)             # identity equality: list.remove stays O(1)
class Request:
    """One submitted generation request moving WAITING -> PREFILL -> DECODE."""
    rid: int
    sid: int
    model_id: str | None         # None: prefill-only (gen_tokens == 0)
    tokens: list
    gen_tokens: int
    first_token: int
    priority: int
    seq: int                     # arrival order (fcfs tiebreak)
    params: object = None        # SamplingParams (None on internal paths)
    tok_hash: int = 0            # precomputed hash of tokens (sibling check)
    worker: object = None        # PrefillWorker, assigned at admission
    alloc: object = None         # CacheManager Allocation (chunk-granular)
    block_table: list = field(default_factory=list)
    done: int = 0                # tokens whose KV is in pages (incl. cached)
    committed: bool = False      # published to the radix index / session
    sibling_bt: list | None = None   # identical-context fast path block table
    resume_seq: object = None    # preempted DecodeSeq this request restores
                                 # (drop-and-recompute path, serving/preempt)

    def __post_init__(self):
        self.tok_hash = hash(tuple(self.tokens))

    @property
    def n(self) -> int:
        return len(self.tokens)

    @property
    def cached_tokens(self) -> int:
        """Prompt tokens served from the prefix cache at admission — the
        cached-history vs cold classification signal (queue.py)."""
        if self.sibling_bt is not None:
            return self.n
        return self.alloc.cached_tokens if self.alloc is not None else 0


class ChunkedScheduler:
    """Owns the engine step loop (both chunked and legacy-eager modes)."""

    def __init__(self, engine, cfg: SchedulerConfig):
        self.engine = engine
        self.cfg = cfg
        self.waiting: list[Request] = []
        self.prefilling: list[Request] = []
        self.active: list = []           # DecodeSeqs (engine dataclass)
        self.stats = SchedStats()
        self.promoted: list[int] = []    # rids in prefill-completion order

    # ------------------------------------------------------------------
    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def add_decode_seq(self, seq) -> None:
        """Register an already-prefilled sequence (legacy eager submit)."""
        self.active.append(seq)

    def has_work(self) -> bool:
        return bool(self.waiting or self.prefilling or self.active
                    or self._swap_parked())

    def _swap_parked(self) -> bool:
        """Swap-mode preemption victims parked off the step loop — still the
        engine's work (they resume and finish) even though they sit in none
        of the three queues."""
        swap = self.engine.swap
        return swap is not None and swap.parked

    def run(self) -> None:
        while self.has_work():
            self.step()

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine step: reap finished sequences (EOS/stop/length/abort —
        their budget slots and pages free BEFORE this step's packing); admit;
        pack prefill chunks under the budget; promote finished prefills
        (zero-copy handoff); advance every active sequence one decode
        token."""
        self.stats.steps += 1
        progress = self._reap_finished()
        # step boundary: apply deferred model churn (finalize drained
        # unregisters — the reap above may have retired a draining model's
        # last sequence — and relayout the fused plane; live sequences'
        # lane indices are re-derived from the new plane this same step, so
        # surviving requests decode bit-identically across the churn)
        self.engine.models.sync()
        # same boundary: observe queue depth / pool occupancy into the
        # metrics registry, then let the autoscaler resize the prefill pool
        # or the decode admission reserve off those signals — worker-set
        # mutations are only legal here, exactly like model churn
        self.engine._observe_step()
        self.engine._autoscale_tick()
        progress += self._admit()
        progress += self._oversub_phase()
        budget = self.cfg.token_budget - len(self.active)
        chunks = self._plan_chunks(budget)
        progress += self._run_chunks(chunks)
        progress += self._promote()
        progress += self._tail_growth_guard()
        progress += self._decode_phase()
        if self.engine.sanitizer is not None:
            # step boundary: every transient ref/alloc has settled, so the
            # pool/index/holder cross-check must hold exactly here
            self.engine.sanitizer.check_step()
        if progress == 0 and (self.waiting or self.prefilling
                              or self._swap_parked()):
            if self.engine.sched_reserve_extra > 0:
                # the autoscaler's extra decode headroom is advisory — it
                # must never wedge the engine. If it is the only thing
                # blocking progress, give it back and retry next step.
                self.engine.sched_reserve_extra = 0
                return
            swap = self.engine.swap
            raise PoolExhausted(
                f"scheduler stalled: {len(self.waiting)} waiting / "
                f"{len(self.prefilling)} prefilling / "
                f"{len(swap.records) if swap is not None else 0} swapped-out "
                f"requests cannot obtain pages and no decode is active to "
                f"free any")

    # ---- oversubscription (serving/preempt.py) -------------------------
    def _oversub_phase(self) -> int:
        """After admission, before chunk packing: resume parked victims when
        pages allow, then preempt low-priority decodes when the highest-
        priority pending request is page-blocked. Runs before the budget is
        computed so a resumed sequence claims its decode slot this step."""
        swap = self.engine.swap
        if swap is None:
            return 0
        progress = swap.resume_step(self)
        progress += swap.preempt_step(self)
        return progress

    def _tail_growth_guard(self) -> int:
        """Right before decode: with overcommit the admission reserve is
        deliberately under-scaled, so the pool may lack the tail pages the
        coming decode step must allocate — evict victims until it cannot
        fail mid-flight."""
        swap = self.engine.swap
        if swap is None:
            return 0
        return swap.grow_guard(self)

    # ---- admission ----------------------------------------------------
    def _admit(self) -> int:
        admitted = 0
        for r in order_requests(list(self.waiting), self.cfg.policy):
            # hold a request whose identical context is already in flight:
            # once the leader promotes, the session fast path serves it
            # without recomputing (mirrors the eager sibling-submit path).
            # Hash-only compare: a collision just delays admission one step;
            # the session fast path below rechecks the exact tokens.
            if any(p.sid == r.sid and p.tok_hash == r.tok_hash
                   for p in self.prefilling):
                continue
            self.waiting.remove(r)
            w = self.engine._pick_worker(r.sid, r.tokens)
            r.worker = w
            self.engine.metrics_registry.trace(r.rid).event(
                "routed", worker=w.wid)
            sc = w.sessions.get(r.sid)
            if sc is not None and sc.tokens == r.tokens:
                # identical-context sibling: the session's pages already hold
                # it — no allocation, no chunks, straight to promote. Pin the
                # pages NOW (promotion may be deferred under pool pressure,
                # and the leader session could end in that window, leaving
                # them evictable); the pin is dropped after the handoff takes
                # its own refs.
                self.engine.block_pool.ref(sc.block_table)
                w.mgr.record_hit(r.n)
                self.engine.stats.prefill_tokens_reused += r.n
                r.sibling_bt = list(sc.block_table)
                r.done = r.n
            else:
                r.alloc = w.mgr.begin(r.tokens)
                r.block_table = list(r.alloc.cached_blocks)
                r.done = r.alloc.cached_tokens
                self.engine.stats.prefill_tokens_reused += r.done
                w.pending_chunk_tokens += r.n - r.done
            if r.resume_seq is not None:
                # drop-and-recompute restore: the cache-cold tail of the
                # victim's stream is genuine recompute work
                self.engine.stats.recompute_tokens += r.n - r.done
            self.prefilling.append(r)
            admitted += 1
        return admitted

    # ---- prefill chunk packing ----------------------------------------
    def _plan_chunks(self, budget: int):
        """Split pending prompts into (request, start, take) chunks, policy
        order, chunk-granular page growth; pool pressure defers a request."""
        page = self.engine.page_size
        chunks = []
        # prefill never takes the pool below the pages active decodes are
        # still entitled to (worst-case tail growth, overcommit-scaled) plus
        # the autoscaler's extra decode headroom, so chunking cannot starve
        # the decode plane mid-flight
        reserve = self._reserve_target()
        pool = self.engine.block_pool
        pending = [r for r in self.prefilling
                   if r.done < r.n and r.sibling_bt is None]
        # cached-history prefills pack ahead of cold prompts (within a
        # priority class): their remaining cold work is a chunk or two, so
        # they reach decode immediately instead of queueing behind cold long
        # prompts' many-step prefills (PPD classification, queue.py)
        for r in order_requests(pending, self.cfg.policy,
                                cached_first=self.cfg.cached_first):
            if budget <= 0:
                break
            take = min(self.cfg.chunk_size, r.n - r.done, budget)
            need = -(-(r.done + take) // page) - len(r.block_table)
            if need > 0:
                if pool.free_count - need < reserve:
                    self.stats.stalls += 1
                    continue          # hold; decode may free pages
                try:
                    fresh = r.worker.mgr.extend(r.alloc, need)
                except PoolExhausted:
                    self.stats.stalls += 1
                    continue
                r.block_table.extend(fresh)
            chunks.append((r, r.done, take))
            budget -= take
        return chunks

    def _run_chunks(self, chunks) -> int:
        """Execute planned chunks; equal-length chunks from different
        requests run as ONE batched base-model forward over the pool."""
        if not chunks:
            return 0
        eng = self.engine
        groups: dict[int, list] = {}
        for r, start, take in chunks:
            groups.setdefault(take, []).append((r, start))
        for S, items in groups.items():
            B = len(items)
            # bucket the chunk block-table width to the next power of two,
            # exactly like the fused decode step buckets decode tables:
            # table growth WITHIN a bucket reuses the jitted chunk-step
            # trace, so prefill retraces stop scaling with prefix length
            # (padding = sentinel page 0, never live KV)
            npages = next_pow2(max(len(r.block_table) for r, _ in items))
            toks = np.zeros((B, S), np.int32)
            bt = np.zeros((B, npages), np.int32)
            pos = np.zeros((B,), np.int32)
            for i, (r, start) in enumerate(items):
                toks[i] = r.tokens[start:start + S]
                bt[i, :len(r.block_table)] = r.block_table
                pos[i] = start
            t0 = time.perf_counter()
            out = base_prefill_chunk(eng.cfg, eng.base_params, toks,
                                     pool=eng.kvpool, block_tables=bt,
                                     pos=pos)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            for r, _ in items:
                r.done += S
                r.worker.pending_chunk_tokens -= S
                r.worker.ewma.observe(S, dt / B)
                eng.metrics_registry.trace(r.rid).event(
                    "chunk_prefilled", tokens=S, done=r.done)
            eng.stats.prefill_tokens_computed += B * S
            self.stats.chunks += B
            self.stats.chunk_tokens += B * S
            self.stats.max_prefill_batch = max(self.stats.max_prefill_batch, B)
        return len(chunks)

    def _decode_reserve(self) -> int:
        """Pages the active decode sequences are still entitled to: the
        worst-case tail growth each committed-to generation may yet need.
        Prefill chunking and decode admission both stay above this line, so
        a running generation can never hit PoolExhausted mid-flight."""
        page = self.engine.page_size
        return sum(
            max(0, -(-(s.pos + s.remaining) // page) - len(s.block_table))
            for s in self.active)

    def _reserve_target(self) -> int:
        """Admission floor: the decode reserve, scaled down by the
        oversubscription factor when preemption is armed — with victims as
        the escape hatch the pool may admit beyond the strict worst case,
        which is exactly the paper's oversubscription lever."""
        reserve = self._decode_reserve()
        swap = self.engine.swap
        if swap is not None and swap.cfg.overcommit > 1.0:
            reserve = -(-reserve // swap.cfg.overcommit)
        return int(reserve) + self.engine.sched_reserve_extra

    # ---- prefill -> decode handoff -------------------------------------
    def _commit_request(self, r: Request) -> None:
        """Publish a fully-prefilled (non-sibling) request for prefix reuse
        + session bookkeeping, exactly once (promotion may retry under pool
        pressure)."""
        if r.committed:
            return
        from repro.serving.engine import PagedSession
        w = r.worker
        w.mgr.commit(r.tokens, r.alloc)
        old = w.sessions.get(r.sid)
        w.sessions[r.sid] = PagedSession(
            r.alloc, list(r.block_table), r.n, list(r.tokens))
        if old is not None:
            w.mgr.release(old.alloc)
        r.committed = True

    def _promote(self) -> int:
        promoted = 0
        page = self.engine.page_size
        pool = self.engine.block_pool
        for r in list(self.prefilling):
            if r.done < r.n:
                continue
            if r.gen_tokens == 0:
                # prefill-only request (SharedContext warm-up): commit the
                # session and finish — no decode model, no handoff, no CoW
                if r.sibling_bt is not None:
                    pool.unref(r.sibling_bt)
                else:
                    self._commit_request(r)
                self.prefilling.remove(r)
                self.promoted.append(r.rid)
                self.engine._finish_prefill_only(r.rid)
                promoted += 1
                continue
            # decode admission control: the handoff's CoW clone plus THIS
            # sequence's worst-case tail growth must fit above the pages
            # already-running decodes are entitled to — otherwise admitting
            # it could deadlock every generation mid-flight
            cow = 1 if r.n % page else 0
            growth = -(-(r.n + r.gen_tokens) // page) - (-(-r.n // page))
            if pool.free_count - cow - growth < self._reserve_target():
                self.stats.stalls += 1
                continue
            bt = r.sibling_bt
            if bt is None:
                self._commit_request(r)
                bt = r.block_table
            try:
                seq = self.engine._handoff_seq(
                    bt, r.n, r.sid, r.model_id, r.params,
                    r.first_token, r.rid, tokens=r.tokens,
                    priority=r.priority)
            except PoolExhausted:
                self.stats.stalls += 1   # CoW clone page unavailable: retry
                continue
            if r.sibling_bt is not None:
                pool.unref(r.sibling_bt)   # handoff holds its own refs now
            self.prefilling.remove(r)
            self.active.append(seq)
            if r.resume_seq is not None:
                # drop-and-recompute restore: graft the preempted victim's
                # identity onto the re-prefilled sequence; the rid already
                # completed its public prefill, so it is not re-promoted
                self.engine.swap.finish_recompute_resume(r, seq)
            else:
                self.promoted.append(r.rid)
            promoted += 1
        return promoted

    # ---- decode --------------------------------------------------------
    def _reap_finished(self) -> int:
        """Retire sequences whose generation is over — length exhausted OR
        terminated early by an eos/stop token (engine.decode_step zeroes
        ``remaining``). Runs at the TOP of every step, so an early finish
        frees its token-budget slot, its decode-reserve pages, and its pool
        pages before this step's packing decisions."""
        still = []
        finished = 0
        for s in self.active:
            if s.remaining > 0:
                still.append(s)
            else:
                self.engine._finish(s)
                finished += 1
        self.active = still
        return finished

    def _decode_phase(self) -> int:
        """Advance every active sequence one token. Model grouping is the
        ENGINE's concern now: the fused decode plane batches all models
        sharing a config into one vmapped forward (engine.decode_step), so
        the scheduler no longer splits the batch by model.

        The engine steps a COPY of the active list: stream callbacks fire
        inside decode_step's bookkeeping loop and may re-enter the engine —
        abort() removes from ``self.active``, an eager generate() appends to
        it — and either mutation mid-enumeration would cross-wire the step's
        token/sequence alignment."""
        if not self.active:
            return 0
        stepped = list(self.active)
        self.engine.decode_step(stepped)
        return len(stepped)          # self.active may have shrunk mid-step
