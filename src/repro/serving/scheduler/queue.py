"""Admission ordering policies for the chunked-prefill scheduler.

fcfs      — strict arrival order (the default; matches the simulator's FIFO
            prefill workers, so sim and engine share queueing semantics).
priority  — higher ``Request.priority`` first, arrival order within a class.
            Starvation-bounded only by the caller giving equal priorities.

The policy orders BOTH admission (waiting -> prefilling) and per-step chunk
budget allocation: under a tight token budget, the head of the order gets its
chunk first, so a high-priority long prompt cannot be head-of-line-blocked by
lower-priority traffic (and vice versa under fcfs, everyone progresses in
arrival order one budget slice at a time).

Cached-history vs cold (PPD, "Not All Prefills Are Equal"): a request whose
prompt largely matched the radix tree is not the same work item as a cold
long prompt — its remaining cold tokens fit in a chunk or two, so serving it
first gets it to decode almost immediately while barely delaying the cold
prompt's many-step prefill. ``cached_first`` partitions the chunk-budget
order accordingly: within a priority class, cached-history requests
(``Request.cached_tokens > 0``) come before cold ones, arrival order within
each partition. Explicit ``priority`` still dominates the heuristic, and the
partition only reorders CHUNK SCHEDULING — token streams are bit-identical
regardless (chunking changes the schedule, never the tokens).
"""
from __future__ import annotations

POLICIES = ("fcfs", "priority")


def is_cached_history(req) -> bool:
    """True if the request's prompt hit a cached prefix at admission (its
    remaining prefill is history-extension, not cold-prompt work)."""
    return req.cached_tokens > 0


def order_requests(requests, policy: str, cached_first: bool = False):
    """Return ``requests`` in scheduling order (stable)."""
    assert policy in POLICIES, policy
    hot = (lambda r: 0 if is_cached_history(r) else 1) if cached_first \
        else (lambda r: 0)
    if policy == "fcfs":
        return sorted(requests, key=lambda r: (hot(r), r.seq))
    return sorted(requests, key=lambda r: (-r.priority, hot(r), r.seq))
