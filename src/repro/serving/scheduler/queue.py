"""Admission ordering policies for the chunked-prefill scheduler.

fcfs      — strict arrival order (the default; matches the simulator's FIFO
            prefill workers, so sim and engine share queueing semantics).
priority  — higher ``Request.priority`` first, arrival order within a class.
            Starvation-bounded only by the caller giving equal priorities.

The policy orders BOTH admission (waiting -> prefilling) and per-step chunk
budget allocation: under a tight token budget, the head of the order gets its
chunk first, so a high-priority long prompt cannot be head-of-line-blocked by
lower-priority traffic (and vice versa under fcfs, everyone progresses in
arrival order one budget slice at a time).
"""
from __future__ import annotations

POLICIES = ("fcfs", "priority")


def order_requests(requests, policy: str):
    """Return ``requests`` in scheduling order (stable)."""
    assert policy in POLICIES, policy
    if policy == "fcfs":
        return sorted(requests, key=lambda r: r.seq)
    return sorted(requests, key=lambda r: (-r.priority, r.seq))
