"""Event-driven simulator for multi-model disaggregated serving.

Reproduces the paper's serving experiments (Figs. 3-4) on TPU cost terms:

  BASELINE      — N independent (prefill, decode) worker pairs, one per
                  specialized model. Every pair owns a private paged KV pool:
                  the same session prefix is prefilled and stored N times
                  (Eq. 8), so per-pool memory pressure is N× higher and LRU
                  eviction sets in early -> prefix-cache misses -> full
                  recompute -> tail-latency collapse under load.
  PREFILLSHARE  — one shared frozen base model across the prefill pool;
                  sessions are pinned to a prefill worker (prefix-locality
                  routing), the cache is computed once and incrementally
                  extended across agent switches, and pages are handed off to
                  ANY decode model (cache-conditioned decoders accept them) —
                  Eq. 9.

Decode workers run continuous batching with a fluid approximation (batch-
dependent inter-token latency re-evaluated on membership change) and model
Appendix-B.2 staging: when resident KV exceeds the decode worker's HBM
budget, handoff/reload cost inflates.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ModelConfig
from repro.kvcache.handoff import HandoffChannel
from repro.kvcache.manager import (CacheManager, CacheStats, PoolExhausted,
                                   kv_bytes_per_token)
from repro.serving.backpressure import B2Policy
from repro.serving.costmodel import CostModel
from repro.serving.router import PrefillRouter
from repro.serving.workload import Session


@dataclass
class ServingConfig:
    mode: str = "prefillshare"          # or "baseline"
    n_models: int = 4
    n_prefill_workers: int = 4
    n_decode_workers: int = 4
    chips_per_worker: int = 4
    hbm_per_worker: float = 4 * 16e9    # chips * 16GB (v5e)
    block_size: int = 16
    max_concurrent: int = 64
    max_decode_batch: int = 64
    staging_penalty: float = 4.0
    handoff_links: int = 4
    b2_policy: str = "staging"   # staging | admission | backpressure | reservation
                                 # (Appendix B.2 alternatives; see backpressure.py)
    router_policy: str = "pinned"  # pinned | least_loaded | spillover (router.py)
    prefill_chunk_tokens: int = 0  # 0 = whole-prompt service; >0 = chunked
                                   # prefill (round-robin between queued
                                   # requests at chunk granularity — the
                                   # same token-budget slicing the real
                                   # engine's scheduler performs)
    eos_prob: float = 0.0          # per-token chance a generation stops
                                   # early (the engine's stop/eos finishes):
                                   # an invocation's realized length is
                                   # min(gen_tokens, 1 + Geometric(p)), so
                                   # early finishes free decode batch slots
                                   # and HBM mid-flight. 0 = exact lengths
                                   # (the historical behaviour).
    churn_interval_s: float = 0.0  # model-lifecycle churn (the engine's
                                   # ModelRegistry): every interval a decode
                                   # model hot-(un)registers mid-workload.
                                   # 0 = static model set (historical).
    churn_rebuild_s: float = 0.02  # registry-rebuild cost per churn event:
                                   # the fused decode plane relayouts (and
                                   # re-jits) at a step boundary, freezing
                                   # every decode worker's progress for this
                                   # long — fig3-style runs price churn with
                                   # exactly this stall.
    autoscale: object = None       # None = static split; True or an
                                   # AutoscaleConfig (serving/autoscale.py)
                                   # = metrics-driven elastic prefill:decode
                                   # scaling: worker pools are built at the
                                   # autoscale max sizes, n_prefill_workers/
                                   # n_decode_workers become the STARTING
                                   # active counts, and a recurring tick
                                   # shifts workers between the pools off
                                   # backlog/occupancy/TTFT signals.
    overcommit: float = 1.0        # admission oversubscription (the engine's
                                   # LocalDisaggEngine(overcommit=)): the
                                   # session cap is multiplied by this, and
                                   # decode HBM overflow is absorbed by the
                                   # host-memory swap tier instead of the
                                   # B.2 staging inflation. 1.0 = historical
                                   # behaviour (no swap tier).
    swap_gbps: float = 10.0        # host<->device swap bandwidth (GB/s) the
                                   # swap tier drains overflow at; each
                                   # preemption stalls the worker for
                                   # excess_bytes / bandwidth (the engine's
                                   # measured-bandwidth SwapCostModel).


@dataclass
class InvocationRecord:
    sid: int
    inv_idx: int
    model_id: int
    issued: float
    ttft: float = 0.0
    done: float = 0.0
    gen_tokens: int = 0          # realized generation length (<= requested)
    finish_reason: str = "length"  # "eos" when eos_prob cut it short
    prefill_cached: int = 0
    prefill_new: int = 0
    staged: bool = False


@dataclass
class _SessionState:
    session: Session
    inv_idx: int = -1
    context: list = field(default_factory=list)
    allocs: dict = field(default_factory=dict)    # worker id -> Allocation
    started: float = 0.0
    records: list = field(default_factory=list)


class _PrefillWorker:
    """Single-server FIFO prefill worker with a paged prefix cache.

    ``chunk_tokens > 0`` models the real engine's chunked scheduler: a
    request is serviced in chunk-sized time slices and re-queued at the TAIL
    between slices, so a long prompt no longer head-of-line-blocks every
    request behind it for its whole service time."""

    def __init__(self, wid, cfg, cost, pool_bytes, block_size,
                 chunk_tokens: int = 0):
        self.wid = wid
        self.cost = cost
        self.chunk_tokens = chunk_tokens
        bpt = kv_bytes_per_token(cfg)
        n_blocks = max(64, int(pool_bytes / (bpt * block_size)))
        self.mgr = CacheManager(cfg, n_blocks, block_size)
        self.busy_until = 0.0
        self.queue = []
        self.busy_time = 0.0
        self.inflight_pages = 0   # worst-case pages of in-service requests

    def service_time(self, n_new, kv_len):
        return self.cost.prefill(max(n_new, 1), kv_len).seconds


class _DecodeWorker:
    """Continuous-batching decode worker (fluid approximation)."""

    def __init__(self, wid, cfg, cost, hbm_bytes, max_batch):
        self.wid = wid
        self.cfg = cfg
        self.cost = cost
        self.hbm = hbm_bytes
        self.max_batch = max_batch
        self.kv_per_tok = kv_bytes_per_token(cfg)
        self.weight_bytes = cfg.param_count() * 2
        self.active = {}        # rid -> dict(remaining, kv_len, meta)
        self.wait = []
        self.last_t = 0.0
        self.gen_tokens = 0
        self.swapped_bytes = 0.0   # overflow parked in the host swap tier

    # -- fluid batching ------------------------------------------------
    def resident_bytes(self):
        return sum(r["kv_len"] * self.kv_per_tok for r in self.active.values())

    def itl(self):
        if not self.active:
            return 0.0
        b = len(self.active)
        avg_kv = np.mean([r["kv_len"] for r in self.active.values()])
        t = self.cost.decode_step(b, avg_kv).seconds
        free = self.hbm - self.weight_bytes
        # swapped-out KV lives in host memory, not HBM: it neither inflates
        # the staging term nor counts against the budget (the swap stall is
        # priced separately, at preemption time)
        over = (max(0.0, self.resident_bytes() - self.swapped_bytes - free)
                / max(free, 1.0))
        return t * (1.0 + 3.0 * over)   # staging/reload inflation (B.2)

    def advance(self, now):
        """Progress all active requests from last_t to now; return finished.
        ``last_t`` never moves backwards: a churn stall parks it in the
        future, and advances inside the frozen window must not rewind it."""
        dt = now - self.last_t
        self.last_t = max(self.last_t, now)
        finished = []
        if not self.active or dt <= 0:
            return finished
        step = self.itl()
        steps = dt / step if step > 0 else 0.0
        for rid, r in list(self.active.items()):
            n = min(r["remaining"], steps)
            r["remaining"] -= n
            r["kv_len"] += n
            self.gen_tokens += n
            if r["remaining"] <= 1e-9:
                finished.append((rid, r))
                del self.active[rid]
        return finished

    def next_completion(self, now):
        if not self.active:
            return None
        step = self.itl()
        rem = min(r["remaining"] for r in self.active.values())
        return now + max(rem, 1e-6) * step


class Simulator:
    def __init__(self, model_cfg: ModelConfig, scfg: ServingConfig,
                 sessions: list[Session], seed: int = 0):
        self.cfg = model_cfg
        self.scfg = scfg
        self.sessions = sessions
        cost = CostModel(model_cfg, chips=scfg.chips_per_worker)
        kv_budget = scfg.hbm_per_worker - model_cfg.param_count() * 2
        assert kv_budget > 0, "worker HBM cannot even hold the weights"
        # elastic scaling (serving/autoscale.py): worker lists are built at
        # the autoscale MAX sizes; only the first n_*_on of each are routable
        # ("active"). A deactivated worker finishes what it holds (queued
        # prefills drain, decoding sequences run out) — it just receives no
        # new work, the same step-boundary semantics as the real engine.
        self.autoscaler = None
        n_pre, n_dec = scfg.n_prefill_workers, scfg.n_decode_workers
        max_pre, max_dec = n_pre, n_dec
        if scfg.autoscale is not None and scfg.mode == "prefillshare":
            from repro.serving.autoscale import AutoscaleConfig, Autoscaler
            acfg = scfg.autoscale
            if acfg is True:
                acfg = AutoscaleConfig(decode_slots=scfg.max_decode_batch)
            self.autoscaler = Autoscaler(acfg)
            acfg = self.autoscaler.cfg
            max_pre = max(n_pre, acfg.max_prefill)
            max_dec = max(n_dec, acfg.max_decode)
        self.prefill = [
            _PrefillWorker(i, model_cfg, cost, kv_budget, scfg.block_size,
                           chunk_tokens=scfg.prefill_chunk_tokens)
            for i in range(max_pre)]
        self.decode = [
            _DecodeWorker(i, model_cfg, cost, scfg.hbm_per_worker,
                          scfg.max_decode_batch)
            for i in range(max_dec)]
        self.n_prefill_on = n_pre
        self.n_decode_on = n_dec
        #: analytic per-token prefill seconds, for pricing queued backlog
        self._prefill_spt = cost.prefill(256, 0).seconds / 256
        self._ttft_window: list[float] = []    # recent TTFTs (p95 signal)
        self.handoff = HandoffChannel(model_cfg, n_links=scfg.handoff_links,
                                      staging_penalty=scfg.staging_penalty)
        max_ctx = max(
            s.system_tokens + sum(i.delta_tokens + i.gen_tokens
                                  for i in s.invocations)
            for s in sessions)
        self.b2 = B2Policy(scfg.b2_policy, model_cfg,
                           hbm_bytes=scfg.hbm_per_worker,
                           weight_bytes=model_cfg.param_count() * 2,
                           max_context_tokens=max_ctx)
        # oversubscription: the swap tier backs more admitted sessions than
        # decode HBM can hold at once (the engine's overcommit= knob)
        self.effective_cap = int(self.b2.session_cap(scfg.max_concurrent)
                                 * max(1.0, scfg.overcommit))
        self.router = PrefillRouter(scfg.n_prefill_workers,
                                    policy=scfg.router_policy)
        self.rng = np.random.default_rng(seed)     # eos_prob length draws
        self.events = []
        self._seq = itertools.count()
        self.admitted = 0
        self.admission_queue = []
        self.states: dict[int, _SessionState] = {}
        self.records: list[InvocationRecord] = []
        self.completed_sessions = []
        self.t_end = 0.0
        self.churn_events = 0
        self.churn_stall_s = 0.0
        self.resize_events = 0
        self.preemptions = 0
        self.swap_stall_s = 0.0
        if scfg.churn_interval_s > 0:
            self._push(scfg.churn_interval_s, "model_churn", None)
        if self.autoscaler is not None:
            self._push(self.autoscaler.cfg.interval_s, "autoscale_tick", None)

    # -- routing (paper §3.3 prefix-aware routing) ----------------------
    def route_prefill(self, st: _SessionState, model_id: int,
                      now: float = 0.0) -> _PrefillWorker:
        if self.scfg.mode != "prefillshare":
            return self.prefill[model_id % len(self.prefill)]
        active = self.prefill[:self.n_prefill_on]
        backlogs = [max(0.0, w.busy_until - now)
                    + 0.05 * len(w.queue) for w in active]
        return active[self.router.pick(st.session.sid, now, backlogs)]

    def route_decode(self, model_id: int) -> _DecodeWorker:
        return self.decode[model_id % self.n_decode_on]

    # -- event plumbing --------------------------------------------------
    def _push(self, t, kind, payload):
        heapq.heappush(self.events, (t, next(self._seq), kind, payload))

    def run(self):
        for s in self.sessions:
            self._push(s.arrival, "arrive", s)
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            getattr(self, f"_on_{kind}")(t, payload)
        return self.summary()

    # -- session lifecycle -------------------------------------------------
    def _on_arrive(self, t, session: Session):
        if (self.admitted >= self.effective_cap
                or not self.b2.try_reserve(session.sid)):
            self.admission_queue.append(session)
            return
        self._admit(t, session)

    def _admit(self, t, session: Session):
        self.admitted += 1
        st = _SessionState(session=session, started=t)
        st.context = session.fresh_tokens(session.system_tokens, salt=0)
        self.states[session.sid] = st
        self._next_invocation(t, st)

    def _next_invocation(self, t, st: _SessionState):
        st.inv_idx += 1
        if st.inv_idx >= len(st.session.invocations):
            self._finish_session(t, st)
            return
        inv = st.session.invocations[st.inv_idx]
        st.context += st.session.fresh_tokens(inv.delta_tokens,
                                              salt=1 + st.inv_idx * 2)
        rec = InvocationRecord(sid=st.session.sid, inv_idx=st.inv_idx,
                               model_id=inv.model_id, issued=t,
                               gen_tokens=inv.gen_tokens)
        st.records.append(rec)
        self.records.append(rec)
        w = self.route_prefill(st, inv.model_id, now=t)
        w.queue.append((st, inv, rec))
        self._kick_prefill(t, w)

    def _kick_prefill(self, t, w: _PrefillWorker):
        if w.busy_until > t or not w.queue:
            return
        # one pass over the queue: a request whose slice cannot obtain pages
        # is HELD at the tail (its computed pages stay pinned) and retried
        # when a later completion releases an allocation — the engine
        # scheduler's backpressure, in event form
        for _ in range(len(w.queue)):
            item = w.queue.pop(0)
            st, inv, rec = item[:3]
            prog = item[3] if len(item) > 3 else None
            if prog is None:             # first slice of this request
                tokens = list(st.context)
                if w.chunk_tokens:
                    # worst-case admission control (the engine's promote
                    # gate, prefill-side): start slicing a new prompt only
                    # if its full page footprint fits alongside the prompts
                    # already in service — round-robin then cannot pin the
                    # pool dry mid-prefill, and tight pools degrade to the
                    # serial service the unchunked mode models
                    bs = w.mgr.pool.block_size
                    need = -(-len(tokens) // bs)
                    if (w.inflight_pages
                            and w.inflight_pages + need > w.mgr.pool.num_blocks):
                        w.queue.append((st, inv, rec))   # unstarted: unpinned
                        continue
                    # chunk-granular growth, mirroring the engine's
                    # scheduler: only the prefix is claimed now; tail pages
                    # arrive with each slice (extend below), so interleaved
                    # long prompts hold computed pages, not whole-prompt
                    # allocations
                    alloc = w.mgr.begin(tokens)
                    w.inflight_pages += need
                else:
                    need = 0
                    alloc = w.mgr.acquire(tokens)  # pool >= one max-ctx req
                    w.mgr.commit(tokens, alloc)
                n_new = alloc.total_tokens - alloc.cached_tokens
                rec.prefill_cached = alloc.cached_tokens
                rec.prefill_new = n_new
                prog = {"alloc": alloc, "tokens": tokens, "n_new": n_new,
                        "done": 0, "pages": need}
            alloc = prog["alloc"]
            remaining = prog["n_new"] - prog["done"]
            chunk = remaining if not w.chunk_tokens else min(w.chunk_tokens,
                                                            remaining)
            if w.chunk_tokens:
                bs = w.mgr.pool.block_size
                covered = alloc.cached_tokens + prog["done"] + chunk
                try:
                    w.mgr.extend(alloc,
                                 -(-covered // bs) - len(alloc.blocks))
                except PoolExhausted:
                    w.queue.append((st, inv, rec, prog))
                    continue
            # chunk service cost accounts for the prefix ALREADY in the
            # cache (cached hit + previously-computed chunks), mirroring the
            # engine's chunk forward attending to the growing paged prefix.
            dur = w.service_time(chunk, alloc.cached_tokens + prog["done"])
            w.busy_until = t + dur
            w.busy_time += dur
            prog["done"] += chunk
            self._push(t + dur, "prefill_chunk_done",
                       (w.wid, st, inv, rec, prog))
            return
        # every queued request is stalled on pool pressure with the worker
        # idle: no in-flight slice will ever release pages -> fail loudly
        # (the engine scheduler raises in the same no-progress situation)
        raise PoolExhausted(
            f"sim prefill worker {w.wid}: {len(w.queue)} chunked requests "
            f"hold partial allocations and none can grow")

    def _on_prefill_chunk_done(self, t, payload):
        wid, st, inv, rec, prog = payload
        w = self.prefill[wid]
        if prog["done"] < prog["n_new"]:
            # requeue at the TAIL: other waiting requests get their slice
            # before this prompt's next chunk (no head-of-line blocking)
            w.queue.append((st, inv, rec, prog))
            self._kick_prefill(t, w)
            return
        if w.chunk_tokens:
            # publish for prefix reuse only once fully computed (the
            # engine's scheduler commits at promote time)
            w.mgr.commit(prog["tokens"], prog["alloc"])
            w.inflight_pages -= prog["pages"]
        # pages stay CACHED (LRU-evictable) for future prefix extension; the
        # decode side consumes its own handed-off copy, so no pin is needed.
        w.mgr.release(prog["alloc"])
        self._kick_prefill(t, w)
        self._try_handoff(t, st, inv, rec)

    def _try_handoff(self, t, st, inv, rec):
        # Hand the shared cache to the decode worker, subject to the B.2
        # policy (backpressure may defer until decode HBM can host the KV).
        dw = self.route_decode(inv.model_id)
        dw.advance(t)
        decision = self.b2.admit_decode(dw.resident_bytes(), len(st.context))
        if not decision.admit:
            self._push(t + decision.delay_hint_s, "handoff_retry",
                       (st, inv, rec))
            return
        free = dw.hbm - dw.weight_bytes - dw.resident_bytes()
        plan = self.handoff.plan(len(st.context), decode_hbm_free_bytes=int(free))
        rec.staged = plan.staged
        self._push(t + plan.seconds, "decode_start", (dw.wid, st, inv, rec))

    def _on_handoff_retry(self, t, payload):
        st, inv, rec = payload
        self._try_handoff(t, st, inv, rec)

    # -- model-lifecycle churn -------------------------------------------
    def _on_model_churn(self, t, _payload):
        """One hot (un)register event: the decode plane's stacked layout is
        rebuilt at a step boundary, which re-jits the fused step — modeled
        as every decode worker's fluid progress freezing for
        ``churn_rebuild_s`` (surviving sequences then resume bit-identically,
        so ONLY the stall is priced, never lost tokens)."""
        stall = self.scfg.churn_rebuild_s
        self.churn_events += 1
        for dw in self.decode:
            finished = dw.advance(t)
            for _rid, r in finished:
                self._decode_finished(t, r)
            if dw.active:
                # progress is frozen during [t, t + stall): advance() clamps
                # on dt <= 0, so the next decode_check simply sees no tokens
                # generated across the rebuild window
                dw.last_t = max(dw.last_t, t + stall)
                self.churn_stall_s += stall
                self._reschedule(t + stall, dw)
        # keep churning only while the workload is live (sessions in flight,
        # queued, or yet to arrive) — a recurring event on a drained
        # simulator would spin the loop forever
        if (self.states or self.admission_queue
                or any(kind == "arrive" for _, _, kind, _ in self.events)):
            self._push(t + self.scfg.churn_interval_s, "model_churn", None)

    # -- metrics-driven elastic scaling ----------------------------------
    def _autoscale_signals(self, t):
        """Control-loop inputs from the live fleet — the same signal set the
        real engine assembles from its metrics registry."""
        from repro.serving.autoscale import AutoscaleSignals
        act_p = self.prefill[:self.n_prefill_on]
        backlog_tokens = 0
        busy_s = 0.0
        for w in act_p:
            busy_s += max(0.0, w.busy_until - t)
            for item in w.queue:
                if len(item) > 3 and item[3] is not None:      # mid-chunks
                    backlog_tokens += item[3]["n_new"] - item[3]["done"]
                else:
                    backlog_tokens += len(item[0].context)
        act_d = self.decode[:self.n_decode_on]
        inflight = sum(len(dw.active) for dw in act_d)
        # occupancy counts DEMAND, not just admitted work: sessions parked in
        # the admission queue (B.2 backpressure) are imminent decode load the
        # slots must absorb — without them the signal stays calm exactly when
        # decode is the bottleneck deferring admissions. inflight_decode stays
        # the admitted truth (the shrink-safety guard needs real residency).
        demand = inflight + len(self.admission_queue)
        slots = self.n_decode_on * self.scfg.max_decode_batch
        # decode KV headroom, the analog of the engine's shared-pool free
        # fraction: under B.2 backpressure a full decode HBM DEFERS handoffs
        # at the prefill side, so neither inflight nor the admission queue
        # ever shows the pressure — the resident-bytes headroom does.
        free_frac = min((max(0.0, 1.0 - dw.resident_bytes()
                             / max(dw.hbm - dw.weight_bytes, 1.0))
                         for dw in act_d), default=1.0)
        recent = self._ttft_window[-64:]
        ttft_p95 = (float(np.percentile(recent, 95)) if recent
                    else float("nan"))
        itls = [dw.itl() for dw in act_d if dw.active]
        return AutoscaleSignals(
            prefill_backlog_tokens=backlog_tokens,
            prefill_backlog_s=(backlog_tokens * self._prefill_spt + busy_s),
            decode_occupancy=demand / max(slots, 1),
            free_page_frac=free_frac,
            ttft_p95_s=ttft_p95,
            itl_p95_s=max(itls) if itls else float("nan"),
            n_prefill=self.n_prefill_on,
            n_decode=self.n_decode_on,
            inflight_decode=inflight)

    def _on_autoscale_tick(self, t, _payload):
        d = self.autoscaler.tick(self._autoscale_signals(t), t)
        if d:
            if d.prefill_delta > 0 and self.n_prefill_on < len(self.prefill):
                self.n_prefill_on += 1
            elif d.prefill_delta < 0 and self.n_prefill_on > 1:
                self.n_prefill_on -= 1
            if d.decode_delta > 0 and self.n_decode_on < len(self.decode):
                self.n_decode_on += 1
            elif d.decode_delta < 0 and self.n_decode_on > 1:
                self.n_decode_on -= 1
            self.router.n = self.n_prefill_on
            self.resize_events += 1
        # keep ticking only while the workload is live (same guard as churn)
        if (self.states or self.admission_queue
                or any(kind == "arrive" for _, _, kind, _ in self.events)):
            self._push(t + self.autoscaler.cfg.interval_s,
                       "autoscale_tick", None)

    def _on_decode_start(self, t, payload):
        wid, st, inv, rec = payload
        dw = self.decode[wid]
        finished = dw.advance(t)
        for rid, r in finished:
            self._decode_finished(t, r)
        rid = (st.session.sid, st.inv_idx)
        # variable-length finishes (the engine's eos/stop semantics): the
        # realized length is geometric-truncated, so a cut-short generation
        # releases its batch slot and resident KV to the fluid model early
        gen = inv.gen_tokens
        if self.scfg.eos_prob > 0:
            # numpy's geometric already returns >= 1 (trials to first
            # success), i.e. exactly "length at which the per-token stop
            # chance first fires"
            gen = min(gen, int(self.rng.geometric(self.scfg.eos_prob)))
            rec.finish_reason = "eos" if gen < inv.gen_tokens else "length"
        rec.gen_tokens = gen
        dw.active[rid] = {"remaining": float(gen),
                          "kv_len": float(len(st.context)),
                          "meta": (st, inv, rec)}
        rec.ttft = t + dw.itl() - rec.issued        # first token after one step
        self._ttft_window.append(rec.ttft)          # autoscaler p95 signal
        self._maybe_swap(t, dw)
        self._reschedule(t, dw)

    def _maybe_swap(self, t, dw: _DecodeWorker):
        """Preempt decode HBM overflow into the host swap tier.

        With ``overcommit > 1`` armed, a worker whose resident KV exceeds the
        HBM budget swaps the excess out at ``swap_gbps`` instead of paying the
        B.2 staging inflation forever: progress freezes for the transfer (the
        engine's gather + device_get), after which the remaining resident set
        decodes at un-inflated speed. Uses the churn-stall idiom — ``last_t``
        is parked in the future and ``advance()`` clamps on dt <= 0."""
        if self.scfg.overcommit <= 1.0 or self.scfg.swap_gbps <= 0:
            return
        # finished sequences take their swapped share with them (the engine
        # discards a finished victim's host entry)
        dw.swapped_bytes = min(dw.swapped_bytes, dw.resident_bytes())
        free = dw.hbm - dw.weight_bytes
        excess = dw.resident_bytes() - dw.swapped_bytes - free
        # hysteresis (the engine's PreemptConfig.hysteresis_steps): per-token
        # residency growth accumulates until it is worth one batched swap,
        # instead of counting a "preemption" every completion check
        if excess <= 0.02 * free:
            return
        stall = excess / (self.scfg.swap_gbps * 1e9)
        dw.swapped_bytes += excess
        dw.last_t = max(dw.last_t, t + stall)
        self.preemptions += 1
        self.swap_stall_s += stall
        # NO _reschedule here: both call sites reschedule right after, and a
        # second push per check would double the event stream every step

    def _reschedule(self, t, dw: _DecodeWorker):
        nxt = dw.next_completion(t)
        if nxt is not None:
            self._push(nxt, "decode_check", dw.wid)

    def _on_decode_check(self, t, wid):
        dw = self.decode[wid]
        finished = dw.advance(t)
        for rid, r in finished:
            self._decode_finished(t, r)
        self._maybe_swap(t, dw)
        self._reschedule(t, dw)

    def _decode_finished(self, t, r):
        st, inv, rec = r["meta"]
        rec.done = t
        # REALIZED generated tokens join the shared context (an eos-cut
        # generation contributes its shorter output, like the real engine)
        st.context += st.session.fresh_tokens(rec.gen_tokens,
                                              salt=2 + st.inv_idx * 2)
        self.t_end = max(self.t_end, t)
        self._next_invocation(t, st)

    def _finish_session(self, t, st: _SessionState):
        del self.states[st.session.sid]
        self.admitted -= 1
        self.b2.release(st.session.sid)
        self.completed_sessions.append((st.session.sid, st.started, t))
        while (self.admission_queue and self.admitted < self.effective_cap
               and self.b2.try_reserve(self.admission_queue[0].sid)):
            self._admit(t, self.admission_queue.pop(0))

    # -- metrics ---------------------------------------------------------
    def summary(self) -> dict:
        recs = [r for r in self.records if r.done > 0]
        sess = self.completed_sessions
        e2e = [done - start for _, start, done in sess]
        ttft = [r.ttft for r in recs]
        total_gen = sum(r.gen_tokens for r in recs)
        makespan = self.t_end - min(s.arrival for s in self.sessions)
        # fleet-wide hit accounting through the SAME rollup the engine's
        # ``stats()`` surface uses, so sim and engine report one number
        agg = CacheStats.merge(w.mgr.stats for w in self.prefill)
        hits, tot = agg.hit_tokens, agg.total_tokens
        return {
            "mode": self.scfg.mode,
            "sessions_done": len(sess),
            "p50_e2e_s": float(np.percentile(e2e, 50)) if e2e else float("nan"),
            "p95_e2e_s": float(np.percentile(e2e, 95)) if e2e else float("nan"),
            "mean_ttft_s": float(np.mean(ttft)) if ttft else float("nan"),
            "p95_ttft_s": float(np.percentile(ttft, 95)) if ttft else float("nan"),
            "throughput_tok_s": total_gen / makespan if makespan > 0 else 0.0,
            "prefix_hit_ratio": hits / tot if tot else 0.0,
            "prefill_busy_frac": float(np.mean(
                [w.busy_time / makespan for w in self.prefill])),
            "evictions": sum(w.mgr.pool.stats.evictions for w in self.prefill),
            "staged_frac": float(np.mean([r.staged for r in recs])) if recs else 0.0,
            "early_stop_frac": float(np.mean(
                [r.finish_reason == "eos" for r in recs])) if recs else 0.0,
            "churn_events": self.churn_events,
            "churn_stall_s": self.churn_stall_s,
            "resize_events": self.resize_events,
            "preemptions": self.preemptions,
            "swap_stall_s": self.swap_stall_s,
            "final_prefill_workers": self.n_prefill_on,
            "final_decode_workers": self.n_decode_on,
        }
