"""Beyond-paper: the Appendix-B.2 alternatives, implemented.

The paper observes that at extreme concurrency, decode-side KV pressure forces
vLLM's CPU staging/reload ("swap-like" behaviour) and throughput drops — and
explicitly leaves the mitigations as future work:

  "alternative designs could mitigate overflow-induced staging via stricter
   admission control, decode-to-prefill backpressure, or per-session
   reservation of GPU-resident KV buffers."   (Appendix B.2)

This module implements all three as pluggable policies for the simulator, and
``benchmarks/b2_alternatives.py`` compares them against the paper's staging
behaviour at the concurrency levels where Fig. 4's throughput rolls over.

Policies (decode-side admission of a handed-off request):
  staging      — paper behaviour: always admit; overflow inflates ITL (B.2).
  admission    — strict: cap concurrent sessions so worst-case resident KV
                 (every session at its max context) fits HBM. No staging ever,
                 but admits fewer sessions.
  backpressure — decode worker exposes free-HBM; the PREFILL worker defers the
                 handoff (holds the request) until the decode side can host
                 the KV resident. Prefill keeps serving other sessions.
  reservation  — per-session KV budget reserved at session admission (max
                 context × bytes/token); sessions beyond the reservable
                 capacity queue at admission. Equivalent to admission control
                 with exact per-session accounting instead of a global cap.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.kvcache.manager import kv_bytes_per_token

POLICIES = ("staging", "admission", "backpressure", "reservation")


class ThroughputEWMA:
    """Measured per-worker prefill throughput (seconds/token), exponentially
    weighted. Replaces the old hardcoded ``_EST_S_PER_TOKEN`` router-backlog
    constant, so the backlog signal tracks the worker's REAL speed (which
    shifts with chunk size, batch composition, and compile caching)."""

    def __init__(self, prior_s_per_token: float = 1e-4, alpha: float = 0.3):
        self.s_per_token = prior_s_per_token
        self.alpha = alpha
        self.n_obs = 0

    def observe(self, tokens: int, seconds: float) -> None:
        if tokens <= 0 or seconds <= 0:
            return
        obs = seconds / tokens
        # Every sample is clamped to 8x the current estimate and blended —
        # including the first, which on a cold worker is ALWAYS JIT
        # trace/compile-dominated (seconds against a ~ms steady state) and
        # would otherwise poison the router signal by orders of magnitude.
        # Genuine regime shifts still converge geometrically (up to ~3x per
        # observation upward, (1-alpha)x downward) from any prior.
        self.s_per_token += self.alpha * (
            min(obs, 8.0 * self.s_per_token) - self.s_per_token)
        self.n_obs += 1

    def backlog_seconds(self, pending_tokens: int) -> float:
        """Chunk-aware backlog estimate: tokens admitted to a worker but not
        yet prefilled, priced at its measured throughput."""
        return pending_tokens * self.s_per_token


@dataclass
class DecodeAdmission:
    """Decision for a handed-off request arriving at a decode worker."""
    admit: bool
    delay_hint_s: float = 0.0      # backpressure: retry after this long


class B2Policy:
    def __init__(self, policy: str, cfg, *, hbm_bytes: float,
                 weight_bytes: float, max_context_tokens: int):
        assert policy in POLICIES, policy
        self.policy = policy
        self.kv_per_tok = kv_bytes_per_token(cfg)
        self.free_budget = hbm_bytes - weight_bytes
        self.max_ctx_bytes = max_context_tokens * self.kv_per_tok
        self.reserved: dict = {}            # session id -> reserved bytes

    # -- session-level admission (reservation policy) --------------------
    def try_reserve(self, sid: int) -> bool:
        if self.policy != "reservation":
            return True
        used = sum(self.reserved.values())
        if used + self.max_ctx_bytes > self.free_budget:
            return False
        self.reserved[sid] = self.max_ctx_bytes
        return True

    def release(self, sid: int) -> None:
        self.reserved.pop(sid, None)

    # -- request-level admission (handoff arrival) ------------------------
    def admit_decode(self, resident_bytes: float, incoming_tokens: int
                     ) -> DecodeAdmission:
        incoming = incoming_tokens * self.kv_per_tok
        if self.policy in ("staging", "admission", "reservation"):
            # staging: always admit (overflow priced as ITL inflation);
            # admission/reservation prevent overflow upstream.
            return DecodeAdmission(admit=True)
        # backpressure: defer the handoff until the KV fits resident
        if resident_bytes + incoming <= self.free_budget:
            return DecodeAdmission(admit=True)
        # retry when roughly one request's worth of KV drains
        return DecodeAdmission(admit=False, delay_hint_s=0.02)

    # -- global session cap (admission policy) ----------------------------
    def session_cap(self, requested_cap: int) -> int:
        if self.policy != "admission":
            return requested_cap
        per_session = self.max_ctx_bytes
        fit = max(1, int(self.free_budget / per_session))
        return min(requested_cap, fit)
