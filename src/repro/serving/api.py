"""Request-centric serving API: the engine's public surface.

The paper's execution pattern — N task-specific models invoked over ONE
shared prompt — is what this module makes the API's main verb:

    with engine.shared_context(system_tokens) as ctx:
        outs = [ctx.generate(agent, params=SamplingParams(max_tokens=32))
                for agent in ("planner", "coder", "reviewer")]
        for out in outs:
            for tok in out:          # streams while the engine runs
                ...

Pieces:
  - ``SamplingParams``: per-request decoding controls (temperature / top_k /
    top_p / seed / max_tokens / stop & EOS ids), executed INSIDE the jitted
    decode step (serving/sampling.py). ``temperature=0`` (the default) is
    bit-identical to the pre-redesign greedy path; seeded sampling is
    reproducible regardless of batch packing (keys fold from (seed, pos)).
  - ``RequestOutput``: a live handle. Tokens stream in as the engine steps —
    iterate it (drives the engine), register callbacks, or call ``result()``
    to drive to completion. Carries the finish reason (eos/stop/length/
    abort) and per-token timestamps (TTFT / inter-token latencies).
  - ``SharedContext``: a first-class shared prefix replacing raw session
    ids: one prefilled prefix that many ``ctx.generate(model_id, tail)``
    calls attach to, released on ``close()``/context-manager exit.
  - ``engine.abort(request)``: cancels a request at ANY lifecycle stage
    (queued, mid-chunk, held under backpressure, decoding) and returns every
    page refcount to baseline.
  - ``engine.models`` (``repro.serving.registry``): the decode-model set as
    a live lifecycle surface — ``register``/``unregister`` while serving;
    requests naming a model the registry does not serve raise the
    first-class ``UnknownModelError`` defined here.

The legacy ``submit``/``invoke``/``result`` surface survives as a thin
deprecated shim over this API (asserted token-identical in tests/test_api.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

#: finish reasons a request can end with
FINISH_EOS = "eos"          # the request's eos_token_id was generated
FINISH_STOP = "stop"        # a stop_token_ids member was generated
FINISH_LENGTH = "length"    # max_tokens reached
FINISH_ABORT = "abort"      # engine.abort() cancelled the request


class UnknownModelError(KeyError):
    """A request named a decode model the engine's ``ModelRegistry`` does not
    currently serve — never registered, already unregistered, or draining
    (unregister pending, accepting no new work). Raised by ``generate`` /
    ``SharedContext.generate`` / the legacy ``submit`` shim BEFORE any pages
    are touched, so a failed submission holds no engine state."""


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls.

    ``temperature=0`` (default) is exact greedy decoding — bit-identical to
    the pre-redesign path. ``top_k <= 0`` and ``top_p >= 1`` disable their
    filters. ``seed`` controls reproducibility: the PRNG key for each
    generated token folds from (seed, absolute position), so a stream never
    depends on what else is batched alongside the request. ``seed=None``
    (default) lets the engine assign a distinct per-request seed — N sampled
    fan-outs over one prompt give N different draws; pass an explicit seed
    to reproduce a stream across runs. ``max_tokens=0`` is a prefill-only
    request (used by SharedContext to warm a prefix). The terminating
    eos/stop token IS included in the output.

    ``priority`` ranks the request for admission ordering (the scheduler's
    ``priority`` policy) AND for oversubscription: with preemption armed
    (``LocalDisaggEngine(preempt=True)``), lower-priority decodes are
    swapped out or dropped-and-recomputed to unblock higher-priority work.
    Higher values are more important; the ``priority=`` kwarg on
    ``generate()`` overrides a nonzero value here."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    max_tokens: int = 16
    stop_token_ids: tuple = ()
    eos_token_id: int | None = None
    priority: int = 0

    def __post_init__(self):
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens < 0:
            raise ValueError(f"max_tokens must be >= 0, got {self.max_tokens}")
        if not isinstance(self.priority, int) or isinstance(self.priority,
                                                            bool):
            raise ValueError(
                f"priority must be an int, got {self.priority!r}")

    def is_stop(self, token: int) -> str | None:
        """Finish reason if ``token`` terminates the stream, else None."""
        if self.eos_token_id is not None and token == self.eos_token_id:
            return FINISH_EOS
        if token in self.stop_token_ids:
            return FINISH_STOP
        return None


class RequestOutput:
    """Live handle for one generation request.

    The engine pushes tokens into it as decode steps complete; consume them
    by iterating (drives the engine until the next token or finish), through
    ``add_callback``, or with ``result()`` (drives to completion). Timing:
    ``ttft`` and ``inter_token_latencies()`` are measured at token-push time,
    so they reflect what a streaming client would observe."""

    def __init__(self, engine, request_id: int, session_id, model_id: str,
                 params: SamplingParams):
        self.engine = engine
        self.request_id = request_id
        self.session_id = session_id
        self.model_id = model_id
        self.params = params
        self.tokens: list[int] = []
        self.finished = False
        self.finish_reason: str | None = None
        self.submit_time = time.perf_counter()
        self.first_token_time: float | None = None
        self.token_times: list[float] = []
        self._callbacks: list = []

    # -- engine side ---------------------------------------------------
    def _push(self, token: int) -> None:
        now = time.perf_counter()
        if self.first_token_time is None:
            self.first_token_time = now
        self.tokens.append(int(token))
        self.token_times.append(now)
        for cb in self._callbacks:
            cb(self, int(token))

    def _mark_finished(self, reason: str) -> None:
        self.finished = True
        self.finish_reason = reason

    # -- client side ---------------------------------------------------
    def add_callback(self, cb) -> "RequestOutput":
        """Register ``cb(request_output, token)``, fired per streamed token
        (already-streamed tokens are replayed immediately)."""
        for t in self.tokens:
            cb(self, t)
        self._callbacks.append(cb)
        return self

    @property
    def ttft(self) -> float | None:
        """Seconds from submission to the first streamed token."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    def inter_token_latencies(self) -> list[float]:
        """Gaps between consecutive streamed tokens, in seconds."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    def __iter__(self):
        """Stream tokens, stepping the engine whenever none are buffered."""
        i = 0
        while True:
            while i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            if self.finished:
                return
            if not self.engine.scheduler.has_work():
                raise RuntimeError(
                    f"request {self.request_id}: engine idle but request "
                    f"unfinished")
            self.engine.step()

    def result(self) -> np.ndarray:
        """Drive the engine until this request finishes; returns the full
        token array (partial if the request was aborted — check
        ``finish_reason``)."""
        for _ in self:
            pass
        return np.asarray(self.tokens, np.int32)

    def abort(self) -> bool:
        """Cancel this request; see ``LocalDisaggEngine.abort``."""
        return self.engine.abort(self)

    def __repr__(self):
        state = self.finish_reason if self.finished else "running"
        return (f"RequestOutput(rid={self.request_id}, model={self.model_id!r}, "
                f"tokens={len(self.tokens)}, {state})")


class SharedContext:
    """A first-class shared prefix: the paper's one-prompt-many-models
    execution pattern as an API object.

    Owns an engine session id internally (no raw sid bookkeeping for the
    caller): the prefix is prefilled once (on entry, unless ``prefill=False``)
    and every ``generate`` attaches a decode model to it, reusing the
    resident pages via the session/radix fast paths. ``extend`` grows the
    prefix across turns (append-only, matching the engine's context rule);
    ``close``/context-manager exit releases the session's pages."""

    def __init__(self, engine, prefix_tokens=(), *, prefill: bool = True):
        self.engine = engine
        self.session_id = engine._new_context_sid()
        self.tokens = [int(t) for t in np.asarray(prefix_tokens)]
        self._closed = False
        if prefill and self.tokens:
            self.prefill()

    # ------------------------------------------------------------------
    def prefill(self) -> None:
        """Ensure the current prefix is resident in the KV pool (a
        prefill-only request: max_tokens=0, no decode model attached)."""
        assert not self._closed, "context is closed"
        self.engine._prefill_context(self.session_id, self.tokens)

    def extend(self, tokens) -> "SharedContext":
        """Append tokens to the shared prefix (observations, tool output,
        previous agents' generations). Lazy: the extension is prefilled by
        the next ``generate``/``prefill`` call."""
        assert not self._closed, "context is closed"
        self.tokens += [int(t) for t in np.asarray(tokens)]
        return self

    def generate(self, model_id: str, prompt_tail=(),
                 params: SamplingParams | None = None, *, priority: int = 0,
                 stream_callback=None) -> RequestOutput:
        """Attach decode model ``model_id`` to the shared prefix (plus an
        optional request-private ``prompt_tail``) and return its streaming
        handle. The tail does NOT join the shared prefix."""
        assert not self._closed, "context is closed"
        toks = self.tokens + [int(t) for t in np.asarray(prompt_tail)]
        return self.engine.generate(model_id, toks, params,
                                    session=self.session_id,
                                    priority=priority,
                                    stream_callback=stream_callback)

    def close(self) -> None:
        """Release the session's pages (refcount -> CACHED, LRU-reusable).
        In-flight requests keep their own page references and finish
        normally; abort them explicitly if their output is unwanted."""
        if not self._closed:
            self._closed = True
            self.engine.end_session(self.session_id)

    def __enter__(self) -> "SharedContext":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def __repr__(self):
        state = "closed" if self._closed else "open"
        return (f"SharedContext(sid={self.session_id}, "
                f"prefix={len(self.tokens)} tok, {state})")
